//! Job scheduler: priority queue, admission control, lifecycle tracking,
//! and batch coalescing.
//!
//! This is the daemon's execution backend and — since the serve refactor —
//! also the engine under `coordinator::BatchService`. Workers block on
//! `next_batch`; jobs are dispatched highest-priority-first (FIFO within a
//! priority band), so an emergency clinical scan submitted after a pile of
//! batch research jobs is served next without killing running solves. A
//! bounded queue provides backpressure: batch/urgent submissions are
//! rejected once `queue_cap` jobs are waiting, emergency submissions are
//! always admitted.
//!
//! Coalescing: when enabled (`set_coalesce`), a worker that dequeues a
//! `Priority::Batch` job also claims up to `max_b - 1` queued batch jobs
//! with the same [`JobRequest::coalesce_key`] — same grid size, variant,
//! precision, algorithm and solver knobs — dwelling up to a bounded window
//! for more arrivals, and hands the whole set to `Executor::execute_batch`
//! so compatible subjects solve through one warm batched executable.
//! Every member keeps its own lifecycle: per-job `started`/`done`/
//! `failed`/`cancelled` events, progress streams, and cancel flags (a
//! cancelled member is masked out of the batch, not the whole batch
//! killed). Urgent/emergency jobs never coalesce.
//!
//! Exactly-once submission: `submit_dedup` checks a client-supplied token
//! against a bounded admission map, so a resubmit after a lost response
//! returns the original job id instead of double-running the solve.
//!
//! The `Executor` trait decouples scheduling from PJRT so the scheduler's
//! invariants (and the daemon's wire protocol) are testable without
//! compiled artifacts; `PjrtExecutor` is the production implementation with
//! the per-worker shared-warm operator cache keyed by
//! `(op, variant, n, precision)` (and `(.., batch)` for batched solves).

use std::cmp::Ordering as CmpOrdering;
use std::collections::{BTreeMap, BinaryHeap, VecDeque};
use std::path::Path;
use std::time::{Duration, Instant};

use crate::error::{Error, ErrorCode, Result};
use crate::field::{Field3, VecField3};
use crate::registration::algorithm::{IterEvent, Session, SolveCx, SolveObserver};
use crate::registration::problem::{RegParams, RegProblem};
use crate::registration::report::RunReport;
use crate::registration::solver::{GaussNewtonKrylov, IterRecord};
use crate::runtime::OpRegistry;
use crate::serve::proto::{JobSpec, Priority};
use crate::serve::store::{StoreStats, VolumeStore};
use crate::util::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering as AtomicOrdering};
use crate::util::sync::{Arc, Condvar, Mutex};

pub type JobId = u64;

/// Observable job lifecycle state.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobState {
    Queued,
    Running,
    Done,
    Failed,
    Cancelled,
}

impl JobState {
    pub fn as_str(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Failed => "failed",
            JobState::Cancelled => "cancelled",
        }
    }

    pub fn parse(s: &str) -> Result<JobState> {
        match s {
            "queued" => Ok(JobState::Queued),
            "running" => Ok(JobState::Running),
            "done" => Ok(JobState::Done),
            "failed" => Ok(JobState::Failed),
            "cancelled" => Ok(JobState::Cancelled),
            other => Err(Error::Serve(format!("unknown job state '{other}'"))),
        }
    }

    pub fn is_terminal(&self) -> bool {
        matches!(self, JobState::Done | JobState::Failed | JobState::Cancelled)
    }
}

/// What a worker executes. Synthetic wire submissions carry a spec (the
/// worker synthesizes the problem against its own registry); uploaded-source
/// submissions carry the spec plus the volumes the daemon resolved from the
/// content-addressed store at admission time (so store eviction can never
/// invalidate an admitted job); the batch API hands over pre-built problems.
#[derive(Clone, Debug)]
pub enum JobPayload {
    Spec(JobSpec),
    Volumes {
        spec: JobSpec,
        m0: Arc<Field3>,
        m1: Arc<Field3>,
        /// Initial velocity resolved from the store at admission (the
        /// request's `warm_start` content id), pinned into the payload so
        /// eviction cannot invalidate an admitted job. The template
        /// driver seeds round R+1 solves with round R's velocities here.
        warm_start: Option<Arc<VecField3>>,
    },
    Problem { problem: RegProblem, params: RegParams },
}

impl JobPayload {
    pub fn name(&self) -> String {
        match self {
            JobPayload::Spec(s) | JobPayload::Volumes { spec: s, .. } => s.name(),
            JobPayload::Problem { problem, .. } => problem.name.clone(),
        }
    }

    /// Batch-coalescing compatibility key, when this payload can coalesce
    /// at all. Spec/volume payloads delegate to
    /// [`JobRequest::coalesce_key`](crate::request::JobRequest::coalesce_key);
    /// pre-built `Problem` payloads never coalesce (their params arrived
    /// outside the request surface, so key agreement cannot be checked).
    pub fn coalesce_key(&self) -> Option<String> {
        match self {
            JobPayload::Spec(s) | JobPayload::Volumes { spec: s, .. } => Some(s.coalesce_key()),
            JobPayload::Problem { .. } => None,
        }
    }
}

/// Live per-iteration progress of a job's solve, fed by the scheduler's
/// `SolveObserver`: what the poll-only control plane (`JobView`, `claire
/// status`) and the v2 `progress` watch event show for running jobs.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Progress {
    /// Accepted iterations so far, across all grid levels.
    pub iters_done: usize,
    /// Grid level of the latest iteration (0 = coarsest; 0 on single-grid).
    pub level: usize,
    /// Regularization weight of the latest iteration's continuation level.
    pub beta: f64,
    /// Objective value at the latest iteration.
    pub j: f64,
    /// Latest relative gradient norm ‖g‖/‖g0‖.
    pub grad_rel: f64,
    /// Latest accepted line-search step length.
    pub alpha: f64,
}

/// Wire-friendly snapshot of one job (flat scalars only; the full
/// `RunReport` stays daemon-side, see `Scheduler::full_report`).
#[derive(Clone, Debug)]
pub struct JobView {
    pub id: JobId,
    pub name: String,
    pub priority: Priority,
    pub state: JobState,
    /// Iterations completed so far (live for running jobs; for a
    /// cancelled job, the partial-history length at the interrupt).
    pub iters_done: Option<usize>,
    /// Latest relative gradient norm reported by the solve observer.
    pub grad_rel: Option<f64>,
    /// Monotonic dispatch counter: lower = started earlier. `None` until
    /// a worker picks the job up (or forever, if cancelled while queued).
    pub dispatch_seq: Option<u64>,
    /// Submit-to-finish seconds (queue wait + solve) for terminal jobs.
    pub latency_s: Option<f64>,
    /// Solve seconds on the worker.
    pub wall_s: Option<f64>,
    pub mismatch_rel: Option<f64>,
    pub iters: Option<usize>,
    /// Grid levels the solve actually ran (from `RunReport::levels`);
    /// `None` until the job is done. A multires job that degraded to fewer
    /// levels than its spec requested is visible here.
    pub levels: Option<usize>,
    pub converged: Option<bool>,
    pub error: Option<String>,
    /// Content id of the solve's final velocity, retained in the volume
    /// store by executors with a store attached (`None` otherwise — stub
    /// executors and storeless embedders). The `reduce` verb resolves
    /// these server-side, so driving a template round never downloads a
    /// velocity field.
    pub velocity: Option<String>,
    /// Content id of the warped moving image m0 ∘ φ⁻¹, retained alongside
    /// the velocity when the transport op is available.
    pub warped: Option<String>,
}

/// One backend's slice of a router-merged [`ServeStats`]: identity,
/// health, live load, and how many jobs the router sent its way. A plain
/// daemon never populates these; the fleet router's federated `stats`
/// verb merges one entry per configured backend.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct NodeStats {
    /// Stable node id learned from the backend's `ping` probe (empty
    /// until the first successful probe).
    pub node: String,
    pub addr: String,
    /// Health as of the router's last probe/forward.
    pub up: bool,
    pub queued: usize,
    pub running: usize,
    pub completed: u64,
    /// Jobs this router routed to the node (its affinity receipt —
    /// distinct from `completed`, which also counts jobs submitted to the
    /// backend directly).
    pub routed: u64,
}

/// Aggregate daemon statistics (the `stats` wire verb).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ServeStats {
    pub submitted: u64,
    pub queued: usize,
    pub running: usize,
    pub completed: u64,
    pub failed: u64,
    pub cancelled: u64,
    /// Submissions refused by admission control (bounded queue).
    pub rejected: u64,
    /// Jobs completed by previous daemon incarnations (from the journal).
    pub prior_completed: u64,
    pub workers: usize,
    /// Operator compilations across all workers' caches.
    pub cache_compiles: u64,
    /// Warm-cache reuses across all workers: > 0 whenever several jobs
    /// share a grid size and variant — the whole point of the daemon.
    pub cache_hits: u64,
    /// Volume-store counters (the serve data plane). The scheduler itself
    /// does not own the store; the daemon overlays these when answering
    /// the stats verb, and embedders without a store report zeros.
    pub store: StoreStats,
    /// Per-backend breakdown of a fleet (router-merged stats only; a
    /// single daemon always reports an empty list, keeping its wire
    /// encoding byte-identical to the pre-router protocol).
    pub nodes: Vec<NodeStats>,
    /// Coalesced dispatches (batches of B >= 2 handed to one executor
    /// call). Zero when coalescing is disabled or never fired, keeping the
    /// wire encoding byte-identical to the pre-batching protocol.
    pub batches: u64,
    /// Member jobs across all coalesced dispatches; mean batch fill is
    /// `coalesced / batches`.
    pub coalesced: u64,
}

struct JobRecord {
    name: String,
    priority: Priority,
    state: JobState,
    payload: Option<JobPayload>,
    submitted_at: Instant,
    dispatch_seq: Option<u64>,
    latency_s: Option<f64>,
    wall_s: Option<f64>,
    error: Option<String>,
    report: Option<RunReport>,
    /// Store content ids of retained solve outputs (see `JobView`).
    velocity: Option<String>,
    warped: Option<String>,
    /// Cooperative cancellation flag, shared with the worker's `SolveCx`:
    /// `cancel` on a running job sets it, and the solver observes it at
    /// the next iteration boundary.
    cancel: Arc<AtomicBool>,
    /// Latest observer-reported progress (survives into terminal states
    /// so a cancelled job's partial work stays visible).
    progress: Option<Progress>,
}

#[derive(Clone, Copy, PartialEq, Eq)]
struct QEntry {
    priority: Priority,
    seq: u64,
    id: JobId,
}

impl Ord for QEntry {
    fn cmp(&self, other: &Self) -> CmpOrdering {
        // Max-heap: highest priority first, then FIFO (lowest seq first).
        self.priority.cmp(&other.priority).then(other.seq.cmp(&self.seq))
    }
}

impl PartialOrd for QEntry {
    fn partial_cmp(&self, other: &Self) -> Option<CmpOrdering> {
        Some(self.cmp(other))
    }
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum ShutdownMode {
    Open,
    /// Finish queued + running work, then workers exit.
    Drain,
    /// Workers exit as soon as their current job finishes.
    Now,
}

#[derive(Default)]
struct Counters {
    submitted: u64,
    completed: u64,
    failed: u64,
    cancelled: u64,
    rejected: u64,
    prior_completed: u64,
    /// Coalesced dispatches (B >= 2) and their total member count.
    batches: u64,
    coalesced: u64,
}

struct State {
    queue: BinaryHeap<QEntry>,
    jobs: BTreeMap<JobId, JobRecord>,
    next_id: JobId,
    next_seq: u64,
    next_dispatch: u64,
    /// Jobs in `Queued` state (the heap may also hold stale entries for
    /// cancelled jobs until a pop skips them — never count the heap).
    queued: usize,
    /// Queued batch/urgent jobs only: the admission-control denominator.
    waiting_normal: usize,
    running: usize,
    /// Terminal job ids in completion order, for bounded retention.
    terminal_order: VecDeque<JobId>,
    shutdown: ShutdownMode,
    counters: Counters,
    /// Per-worker cumulative (compiles, hits) from each worker's operator
    /// cache; summed in `stats`.
    worker_cache: BTreeMap<usize, (u64, u64)>,
    /// Exactly-once admission map: client dedup token -> admitted job id.
    /// Bounded by `dedup_order` (insertion order, capped at `retention`).
    dedup: BTreeMap<String, JobId>,
    dedup_order: VecDeque<String>,
}

impl State {
    fn note_dequeued(&mut self, priority: Priority) {
        self.queued = self.queued.saturating_sub(1);
        if priority < Priority::Emergency {
            self.waiting_normal = self.waiting_normal.saturating_sub(1);
        }
    }

    /// Record a terminal transition and evict the oldest terminal records
    /// beyond `retention` so a long-lived daemon's history stays bounded.
    fn note_terminal(&mut self, id: JobId, retention: usize) {
        self.terminal_order.push_back(id);
        while self.terminal_order.len() > retention {
            if let Some(old) = self.terminal_order.pop_front() {
                self.jobs.remove(&old);
            }
        }
    }
}

struct Inner {
    st: Mutex<State>,
    cv: Condvar,
    queue_cap: usize,
    /// Max terminal job records kept for status queries.
    retention: usize,
    workers: usize,
    /// Coalescing config: max batch extent (< 2 disables) and how long a
    /// worker dwells for more compatible arrivals before dispatching a
    /// partial batch. Atomics so the daemon can configure after workers
    /// exist and tests can flip it without a builder.
    coalesce_b: AtomicUsize,
    coalesce_ms: AtomicU64,
}

/// Lifecycle event, surfaced to the optional sink (the daemon journals
/// these so a restarted process can report prior completed work) and
/// broadcast to `watch` subscribers via the event bus.
#[derive(Clone, Debug)]
pub enum JobEvent {
    /// Admission. `dedup` carries the client's exactly-once token when one
    /// was supplied, so the journal can reseed the admission map on replay.
    Submitted { id: JobId, name: String, priority: Priority, dedup: Option<String> },
    /// A worker picked the job up (`queued → running`). Broadcast to
    /// watch subscribers; the journal skips it (transient state).
    Started { id: JobId, name: String },
    /// One accepted solver iteration of a running job. Broadcast to watch
    /// subscribers (the v2 `progress` event); the journal skips it —
    /// per-iteration lines would swamp an audit trail.
    Progress { id: JobId, name: String, progress: Progress },
    /// Terminal transition of a dispatched job: `done`, `failed`, or —
    /// when a running solve observed its cancellation flag — `cancelled`.
    Finished { id: JobId, name: String, state: JobState, wall_s: f64, error: Option<String> },
    /// A *queued* job was cancelled before any worker picked it up.
    Cancelled { id: JobId, name: String },
}

type EventSink = Box<dyn Fn(&JobEvent) + Send + Sync>;

// -- Watch event bus --------------------------------------------------------

/// Default bound on one watch subscriber's pending-event queue. Generous
/// for a reader that keeps up (events are tiny), small enough that a
/// wedged TCP peer costs bounded memory before being dropped as lagged.
pub const WATCH_QUEUE_CAP: usize = 256;

/// One job state transition — or per-iteration progress beat — as
/// observed by a `watch` subscriber.
#[derive(Clone, Debug)]
pub struct WatchEvent {
    pub id: JobId,
    pub name: String,
    pub state: JobState,
    /// Worker-side solve seconds; present on terminal transitions only.
    pub wall_s: Option<f64>,
    /// Failure message; present on `failed` only.
    pub error: Option<String>,
    /// Per-iteration beat of a running solve (`state` stays `running`);
    /// `None` on lifecycle transitions.
    pub progress: Option<Progress>,
}

/// What a subscriber receives from [`WatchHandle::recv`].
#[derive(Clone, Debug)]
pub enum BusMsg {
    Event(WatchEvent),
    /// Terminal: the subscriber fell behind its bounded queue and was
    /// dropped by the publisher. No further messages will arrive.
    Lagged,
}

struct SubState {
    q: VecDeque<BusMsg>,
    lagged: bool,
    closed: bool,
}

/// Bounded per-subscriber queue. Publishers never block on it: a full
/// queue flips the subscriber to lagged (appending the terminal marker)
/// and the publisher forgets it — a slow `watch` connection can never
/// stall a worker recording a job transition.
struct SubQueue {
    cap: usize,
    st: Mutex<SubState>,
    cv: Condvar,
}

impl SubQueue {
    fn new(cap: usize) -> SubQueue {
        SubQueue {
            cap: cap.max(1),
            st: Mutex::new(SubState { q: VecDeque::new(), lagged: false, closed: false }),
            cv: Condvar::new(),
        }
    }

    /// Enqueue for this subscriber. Returns `false` when the subscriber
    /// is finished (closed, or just now flipped to lagged) and should be
    /// dropped from the publisher's list.
    fn push(&self, msg: BusMsg) -> bool {
        let mut st = self.st.lock().unwrap();
        if st.closed || st.lagged {
            return false;
        }
        if st.q.len() >= self.cap {
            // One slot past the cap holds the terminal marker, so the
            // subscriber learns *why* its stream ended.
            st.lagged = true;
            st.q.push_back(BusMsg::Lagged);
            self.cv.notify_all();
            return false;
        }
        st.q.push_back(msg);
        self.cv.notify_all();
        true
    }

    /// Blocking receive; `None` means no further messages will arrive
    /// (unsubscribed, or lagged and fully drained).
    fn recv(&self) -> Option<BusMsg> {
        let mut st = self.st.lock().unwrap();
        loop {
            if let Some(m) = st.q.pop_front() {
                return Some(m);
            }
            if st.closed || st.lagged {
                return None;
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    fn close(&self) {
        let mut st = self.st.lock().unwrap();
        st.closed = true;
        self.cv.notify_all();
    }
}

/// Subscription handle returned by [`Scheduler::watch`]. Receive with
/// [`recv`](WatchHandle::recv); release with [`Scheduler::unwatch`].
pub struct WatchHandle {
    id: u64,
    q: Arc<SubQueue>,
}

impl WatchHandle {
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Blocking receive; `None` means the stream ended (unsubscribed or
    /// lagged-and-drained).
    pub fn recv(&self) -> Option<BusMsg> {
        self.q.recv()
    }
}

#[derive(Default)]
struct SubRegistry {
    next_id: u64,
    subs: Vec<(u64, Arc<SubQueue>)>,
}

/// Cloneable handle to the shared scheduler.
#[derive(Clone)]
pub struct Scheduler {
    inner: Arc<Inner>,
    /// Events are *sequenced* under the state lock (pushed here) but
    /// *delivered* to the sink and bus outside it, so journal disk stalls
    /// never block submit/status/worker traffic. The sink lock doubles as
    /// the single-flusher guard: whoever holds it drains the queue FIFO,
    /// so the journal and every watch subscriber observe one sequence.
    events: Arc<Mutex<VecDeque<JobEvent>>>,
    sink: Arc<Mutex<Option<EventSink>>>,
    /// Watch subscribers (the v2 `watch` verb and in-process observers).
    subs: Arc<Mutex<SubRegistry>>,
}

impl Scheduler {
    /// `queue_cap` bounds the number of *waiting* batch/urgent jobs
    /// (emergency jobs are exempt and do not count toward the bound);
    /// `workers` is advisory (reported in stats). Terminal job records are
    /// retained for status queries up to `4 * queue_cap` (min 1024), then
    /// evicted oldest-first so a long-lived daemon stays bounded.
    pub fn new(queue_cap: usize, workers: usize) -> Scheduler {
        Scheduler {
            inner: Arc::new(Inner {
                st: Mutex::new(State {
                    queue: BinaryHeap::new(),
                    jobs: BTreeMap::new(),
                    next_id: 1,
                    next_seq: 0,
                    next_dispatch: 0,
                    queued: 0,
                    waiting_normal: 0,
                    running: 0,
                    terminal_order: VecDeque::new(),
                    shutdown: ShutdownMode::Open,
                    counters: Counters::default(),
                    worker_cache: BTreeMap::new(),
                    dedup: BTreeMap::new(),
                    dedup_order: VecDeque::new(),
                }),
                cv: Condvar::new(),
                queue_cap: queue_cap.max(1),
                retention: (queue_cap.max(1) * 4).max(1024),
                workers: workers.max(1),
                coalesce_b: AtomicUsize::new(1),
                coalesce_ms: AtomicU64::new(0),
            }),
            events: Arc::new(Mutex::new(VecDeque::new())),
            sink: Arc::new(Mutex::new(None)),
            subs: Arc::new(Mutex::new(SubRegistry::default())),
        }
    }

    /// Subscribe to job state transitions with the default queue bound.
    pub fn watch(&self) -> WatchHandle {
        self.watch_with_cap(WATCH_QUEUE_CAP)
    }

    /// Subscribe with an explicit per-subscriber queue bound (tests use
    /// tiny caps to exercise the lagged path).
    pub fn watch_with_cap(&self, cap: usize) -> WatchHandle {
        let mut reg = self.subs.lock().unwrap();
        reg.next_id += 1;
        let id = reg.next_id;
        let q = Arc::new(SubQueue::new(cap));
        reg.subs.push((id, q.clone()));
        WatchHandle { id, q }
    }

    /// Whether a subscription is still registered with the publisher.
    /// Lagged subscribers are dropped at publish time, so this goes false
    /// as soon as a watcher falls behind — the daemon uses it to let a
    /// connection re-issue `watch` after a `lagged` stream ended.
    pub fn is_watching(&self, sub_id: u64) -> bool {
        self.subs.lock().unwrap().subs.iter().any(|(id, _)| *id == sub_id)
    }

    /// Drop a subscription: pending messages are discarded and the
    /// subscriber's `recv` returns `None`. Idempotent.
    pub fn unwatch(&self, sub_id: u64) {
        let mut reg = self.subs.lock().unwrap();
        if let Some(pos) = reg.subs.iter().position(|(id, _)| *id == sub_id) {
            let (_, q) = reg.subs.swap_remove(pos);
            q.close();
        }
    }

    /// Install the lifecycle event sink (journal). Called before workers
    /// start. The sink observes lifecycle order (a job's `Submitted`
    /// always precedes its `Finished`) and runs outside the state lock;
    /// it must not call back into the scheduler.
    pub fn set_event_sink(&self, sink: EventSink) {
        *self.sink.lock().unwrap() = Some(sink);
    }

    /// Queue an event in sequence. Must be called with the state lock
    /// held (that is what defines the sequence); cheap, memory-only.
    fn emit_locked(&self, ev: JobEvent) {
        self.events.lock().unwrap().push_back(ev);
    }

    /// Deliver queued events to the sink and the watch bus, FIFO. Called
    /// after the state lock is released. The sink lock serializes
    /// flushers, so a thread blocked here never holds up scheduler state —
    /// and a contended flusher's events are drained by whoever currently
    /// holds the sink. Bus pushes never block (bounded queues, lagged
    /// drop), so the journal write is the only potentially slow step.
    fn flush_events(&self) {
        let sink = self.sink.lock().unwrap();
        loop {
            let ev = self.events.lock().unwrap().pop_front();
            let Some(ev) = ev else { break };
            if let Some(f) = sink.as_ref() {
                f(&ev);
            }
            self.publish(&ev);
        }
    }

    /// Broadcast one lifecycle event to every watch subscriber, dropping
    /// subscribers that are gone or just flipped to lagged.
    fn publish(&self, ev: &JobEvent) {
        let mut reg = self.subs.lock().unwrap();
        // No subscribers (the common batch-driver case): skip building the
        // transition — this runs on the submit/dispatch/complete hot path.
        if reg.subs.is_empty() {
            return;
        }
        let transition = match ev {
            JobEvent::Submitted { id, name, .. } => WatchEvent {
                id: *id,
                name: name.clone(),
                state: JobState::Queued,
                wall_s: None,
                error: None,
                progress: None,
            },
            JobEvent::Started { id, name } => WatchEvent {
                id: *id,
                name: name.clone(),
                state: JobState::Running,
                wall_s: None,
                error: None,
                progress: None,
            },
            JobEvent::Progress { id, name, progress } => WatchEvent {
                id: *id,
                name: name.clone(),
                state: JobState::Running,
                wall_s: None,
                error: None,
                progress: Some(*progress),
            },
            JobEvent::Finished { id, name, state, wall_s, error } => WatchEvent {
                id: *id,
                name: name.clone(),
                state: *state,
                wall_s: Some(*wall_s),
                error: error.clone(),
                progress: None,
            },
            JobEvent::Cancelled { id, name } => WatchEvent {
                id: *id,
                name: name.clone(),
                state: JobState::Cancelled,
                wall_s: None,
                error: None,
                progress: None,
            },
        };
        reg.subs.retain(|(_, q)| q.push(BusMsg::Event(transition.clone())));
    }

    /// Seed the completed-work counter from a replayed journal.
    pub fn seed_prior_completed(&self, n: u64) {
        self.inner.st.lock().unwrap().counters.prior_completed = n;
    }

    /// Seed the job-id counter past ids used by previous daemon
    /// incarnations (journal replay), so audit lines from different
    /// incarnations never collide on `id`. Never moves the counter
    /// backwards.
    pub fn seed_next_id(&self, next: JobId) {
        let mut st = self.inner.st.lock().unwrap();
        st.next_id = st.next_id.max(next);
    }

    /// Admit a job, or reject it (queue full / shutting down). Emergency
    /// jobs bypass the queue bound: the clinic never gets a busy signal.
    pub fn submit(&self, priority: Priority, payload: JobPayload) -> Result<JobId> {
        self.submit_dedup(priority, payload, None)
    }

    /// `submit` with an optional exactly-once token. A token already in
    /// the admission map short-circuits to the original job id — no new
    /// job, no new events — so a client resubmitting after a transport
    /// failure cannot double-run a solve. The token is checked before the
    /// queue bound: a retry of an admitted job must succeed even when the
    /// queue has since filled.
    pub fn submit_dedup(
        &self,
        priority: Priority,
        payload: JobPayload,
        dedup: Option<String>,
    ) -> Result<JobId> {
        let name = payload.name();
        let id;
        {
            let mut st = self.inner.st.lock().unwrap();
            if st.shutdown != ShutdownMode::Open {
                return Err(Error::wire(
                    ErrorCode::ShuttingDown,
                    "daemon is shutting down",
                ));
            }
            if let Some(tok) = &dedup {
                if let Some(&prior) = st.dedup.get(tok) {
                    return Ok(prior);
                }
            }
            if priority < Priority::Emergency && st.waiting_normal >= self.inner.queue_cap {
                st.counters.rejected += 1;
                return Err(Error::wire(
                    ErrorCode::QueueFull,
                    format!(
                        "queue full ({} waiting, cap {})",
                        st.waiting_normal,
                        self.inner.queue_cap
                    ),
                ));
            }
            id = st.next_id;
            st.next_id += 1;
            let seq = st.next_seq;
            st.next_seq += 1;
            st.jobs.insert(
                id,
                JobRecord {
                    name: name.clone(),
                    priority,
                    state: JobState::Queued,
                    payload: Some(payload),
                    submitted_at: Instant::now(),
                    dispatch_seq: None,
                    latency_s: None,
                    wall_s: None,
                    error: None,
                    report: None,
                    velocity: None,
                    warped: None,
                    cancel: Arc::new(AtomicBool::new(false)),
                    progress: None,
                },
            );
            st.queue.push(QEntry { priority, seq, id });
            st.queued += 1;
            if priority < Priority::Emergency {
                st.waiting_normal += 1;
            }
            st.counters.submitted += 1;
            if let Some(tok) = &dedup {
                note_dedup(&mut st, tok, id, self.inner.retention);
            }
            // Sequence under the state lock: the journal must see
            // Submitted before any worker can sequence this job's
            // Finished.
            self.emit_locked(JobEvent::Submitted { id, name, priority, dedup });
        }
        self.inner.cv.notify_one();
        self.flush_events();
        Ok(id)
    }

    /// Reseed the exactly-once admission map from a replayed journal, so a
    /// client retrying across a daemon restart still gets its original id
    /// back instead of a duplicate job. Never overwrites a live entry.
    pub fn seed_dedup(&self, token: &str, id: JobId) {
        let mut st = self.inner.st.lock().unwrap();
        if !st.dedup.contains_key(token) {
            note_dedup(&mut st, token, id, self.inner.retention);
        }
    }

    /// Configure batch coalescing: `max_b < 2` disables it (every dispatch
    /// is a singleton, exactly the pre-batching behavior); `window_ms`
    /// bounds how long a worker holding a partial batch dwells for more
    /// compatible arrivals. Takes effect on the next dispatch.
    pub fn set_coalesce(&self, max_b: usize, window_ms: u64) {
        // Relaxed per the config-cell policy (util/sync.rs): these are
        // self-contained values read independently at dispatch time — no
        // other memory is published through them, and a dispatch racing a
        // reconfigure may use either the old or new bound, both valid.
        self.inner.coalesce_b.store(max_b.max(1), AtomicOrdering::Relaxed);
        self.inner.coalesce_ms.store(window_ms, AtomicOrdering::Relaxed);
    }

    /// Blocking highest-priority pop. Returns `None` when the scheduler is
    /// draining and the queue is empty, or shutting down now.
    pub fn next_job(&self, _worker: usize) -> Option<(JobId, JobPayload)> {
        let dispatched = {
            let mut st = self.inner.st.lock().unwrap();
            loop {
                if st.shutdown == ShutdownMode::Now {
                    break None;
                }
                // Pop, skipping stale entries: jobs cancelled while queued,
                // and cancelled jobs whose record retention already evicted.
                let mut found = None;
                while let Some(entry) = st.queue.pop() {
                    let dispatch = st.next_dispatch;
                    let Some(rec) = st.jobs.get_mut(&entry.id) else { continue };
                    if rec.state != JobState::Queued {
                        continue;
                    }
                    rec.state = JobState::Running;
                    rec.dispatch_seq = Some(dispatch);
                    let payload =
                        rec.payload.take().expect("queued job still holds its payload");
                    let name = rec.name.clone();
                    st.note_dequeued(entry.priority);
                    st.next_dispatch += 1;
                    st.running += 1;
                    // Sequence the running transition under the state lock
                    // (delivered to watchers after it is released, below).
                    self.emit_locked(JobEvent::Started { id: entry.id, name });
                    found = Some((entry.id, payload));
                    break;
                }
                if found.is_some() {
                    break found;
                }
                if st.shutdown == ShutdownMode::Drain {
                    break None;
                }
                st = self.inner.cv.wait(st).unwrap();
            }
        };
        if dispatched.is_some() {
            self.flush_events();
        }
        dispatched
    }

    /// Blocking dispatch of one *batch*: the highest-priority job plus —
    /// when coalescing is enabled and the leader is a `Priority::Batch`
    /// job with a coalesce key — up to `max_b - 1` queued batch jobs with
    /// the same key, claimed now or within the dwell window. Every member
    /// is transitioned `queued -> running` individually (own `started`
    /// event, own dispatch_seq), so downstream lifecycle handling is
    /// per-job exactly as if each had been dispatched alone. Returns
    /// `None` like [`next_job`](Scheduler::next_job) on shutdown.
    ///
    /// Urgent/emergency leaders never coalesce and never dwell; a
    /// draining scheduler claims compatible queued work but skips the
    /// dwell (nothing new is coming).
    pub fn next_batch(&self, worker: usize) -> Option<Vec<(JobId, JobPayload)>> {
        let (lead_id, lead_payload) = self.next_job(worker)?;
        let max_b = self.inner.coalesce_b.load(AtomicOrdering::Relaxed);
        let window_ms = self.inner.coalesce_ms.load(AtomicOrdering::Relaxed);
        let lead_batch = {
            let st = self.inner.st.lock().unwrap();
            st.jobs.get(&lead_id).map(|r| r.priority) == Some(Priority::Batch)
        };
        let key = match lead_payload.coalesce_key() {
            Some(k) if max_b >= 2 && lead_batch => k,
            _ => return Some(vec![(lead_id, lead_payload)]),
        };
        let mut members = vec![(lead_id, lead_payload)];
        let deadline = Instant::now() + Duration::from_millis(window_ms);
        let mut st = self.inner.st.lock().unwrap();
        let mut repushed;
        loop {
            // Claim every queued batch job matching the leader's key,
            // setting aside (and re-pushing) everything else. The leader
            // was the highest-priority job when popped, so anything set
            // aside here is either stale or arrived during the dwell.
            let mut aside = Vec::new();
            while members.len() < max_b {
                let Some(entry) = st.queue.pop() else { break };
                let Some(rec) = st.jobs.get_mut(&entry.id) else { continue };
                if rec.state != JobState::Queued {
                    continue;
                }
                let matches = entry.priority == Priority::Batch
                    && rec.payload.as_ref().and_then(|p| p.coalesce_key()).as_deref()
                        == Some(key.as_str());
                if !matches {
                    aside.push(entry);
                    continue;
                }
                rec.state = JobState::Running;
                rec.dispatch_seq = Some(st.next_dispatch);
                let payload = rec.payload.take().expect("queued job still holds its payload");
                let name = rec.name.clone();
                st.note_dequeued(entry.priority);
                st.next_dispatch += 1;
                st.running += 1;
                self.emit_locked(JobEvent::Started { id: entry.id, name });
                members.push((entry.id, payload));
            }
            let interrupt = !aside.is_empty();
            repushed = interrupt;
            for e in aside {
                st.queue.push(e);
            }
            if members.len() >= max_b || st.shutdown != ShutdownMode::Open || interrupt {
                // Full, draining, or other-priority work arrived — a
                // dwelling batch must never delay an urgent scan, so any
                // set-aside traffic dispatches what we have.
                break;
            }
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (g, _) = self.inner.cv.wait_timeout(st, deadline - now).unwrap();
            st = g;
        }
        if members.len() >= 2 {
            st.counters.batches += 1;
            st.counters.coalesced += members.len() as u64;
        }
        drop(st);
        // Missed-notify fix (found by the loom dwell-interrupt model): the
        // submit that woke this dweller spent its `notify_one` on us, and
        // we re-pushed its job instead of running it. Without a re-notify
        // an idle worker sleeps on the condvar while work sits queued
        // until the *next* submit or shutdown. `notify_all` because
        // several set-aside entries may need several workers.
        if repushed {
            self.inner.cv.notify_all();
        }
        self.flush_events();
        Some(members)
    }

    /// Record a finished job. `wall_s` is the worker-side solve time. A
    /// solve that observed its cancellation flag (`Error::Cancelled`)
    /// lands in `Cancelled` — the `running → cancelled` transition — with
    /// its partial-history length preserved in the progress view.
    pub fn complete(&self, id: JobId, result: Result<ExecOutcome>, wall_s: f64) {
        let mut st = self.inner.st.lock().unwrap();
        let Some(rec) = st.jobs.get_mut(&id) else { return };
        let latency = rec.submitted_at.elapsed().as_secs_f64();
        rec.latency_s = Some(latency);
        rec.wall_s = Some(wall_s);
        match result {
            Ok(outcome) => {
                rec.state = JobState::Done; // lifecycle: running -> done
                rec.report = Some(outcome.report);
                rec.velocity = outcome.velocity;
                rec.warped = outcome.warped;
            }
            Err(Error::Cancelled { history }) => {
                rec.state = JobState::Cancelled; // lifecycle: running -> cancelled
                // Keep the partial work visible even when the executor
                // never routed an observer (the history is authoritative;
                // observer-fed progress can only match it).
                let p = rec.progress.get_or_insert(Progress {
                    iters_done: 0,
                    level: 0,
                    beta: f64::NAN,
                    j: f64::NAN,
                    grad_rel: f64::NAN,
                    alpha: f64::NAN,
                });
                p.iters_done = p.iters_done.max(history.len());
                if let Some(last) = history.last() {
                    p.beta = last.level_beta;
                    p.j = last.j;
                    p.grad_rel = last.grad_rel;
                    p.alpha = last.alpha;
                }
            }
            Err(e) => {
                rec.state = JobState::Failed; // lifecycle: running -> failed
                rec.error = Some(e.to_string());
            }
        }
        let state = rec.state;
        let ev = JobEvent::Finished {
            id,
            name: rec.name.clone(),
            state,
            wall_s,
            error: rec.error.clone(),
        };
        st.running = st.running.saturating_sub(1);
        match state {
            JobState::Done => st.counters.completed += 1,
            JobState::Cancelled => st.counters.cancelled += 1,
            _ => st.counters.failed += 1,
        }
        st.note_terminal(id, self.inner.retention);
        self.emit_locked(ev);
        drop(st);
        self.flush_events();
    }

    /// Cancel a job. Queued jobs cancel immediately (never dispatched);
    /// *running* jobs are interrupted cooperatively — the shared flag in
    /// the worker's `SolveCx` trips at the solver's next iteration
    /// boundary, and the job completes as `running → cancelled` with its
    /// partial history. Terminal jobs are final. A running job whose
    /// solve finishes before the next boundary still completes `done` —
    /// the flag is a request, not preemption.
    pub fn cancel(&self, id: JobId) -> Result<()> {
        let mut st = self.inner.st.lock().unwrap();
        let Some(rec) = st.jobs.get_mut(&id) else {
            return Err(Error::wire(ErrorCode::UnknownJob, format!("no such job {id}")));
        };
        match rec.state {
            JobState::Queued => {
                rec.state = JobState::Cancelled;
                rec.payload = None;
                let priority = rec.priority;
                let ev = JobEvent::Cancelled { id, name: rec.name.clone() };
                // The stale heap entry is skipped at pop time, but the
                // admission counters must release the slot immediately.
                st.note_dequeued(priority);
                st.counters.cancelled += 1;
                st.note_terminal(id, self.inner.retention);
                self.emit_locked(ev);
                drop(st);
                self.flush_events();
                Ok(())
            }
            JobState::Running => {
                // The transition is recorded (journaled, streamed) when
                // the worker actually observes the flag and completes the
                // job — not here, where the solve is still running.
                //
                // Release pairs with the Acquire load in
                // `SolveCx::cancelled` (the signal-flag policy in
                // util/sync.rs): everything the canceller wrote before
                // requesting the stop is visible to the solver thread
                // that observes the flag at its next iteration boundary.
                rec.cancel.store(true, AtomicOrdering::Release);
                Ok(())
            }
            other => Err(Error::wire(
                ErrorCode::InvalidState,
                format!("job {id} is {} and cannot be cancelled", other.as_str()),
            )),
        }
    }

    /// Build the observer/cancellation context a worker threads into
    /// `Executor::execute` for job `id`: the record's shared cancel flag
    /// plus a progress sink feeding `JobView` and the `progress` events.
    pub fn solve_cx(&self, id: JobId) -> SolveCx {
        let flag = {
            let st = self.inner.st.lock().unwrap();
            st.jobs.get(&id).map(|r| r.cancel.clone())
        };
        let mut cx = SolveCx::new()
            .with_observer(Arc::new(ProgressSink { sched: self.clone(), id }));
        if let Some(flag) = flag {
            cx = cx.with_cancel(flag);
        }
        cx
    }

    /// Record one solver iteration of a running job and broadcast the
    /// `progress` event. Called from the worker thread via `ProgressSink`.
    fn note_progress(&self, id: JobId, ev: &IterEvent<'_>) {
        let mut st = self.inner.st.lock().unwrap();
        let Some(rec) = st.jobs.get_mut(&id) else { return };
        let iters_done = rec.progress.map_or(0, |p| p.iters_done) + 1;
        let progress = Progress {
            iters_done,
            level: ev.level,
            beta: ev.record.level_beta,
            j: ev.record.j,
            grad_rel: ev.record.grad_rel,
            alpha: ev.record.alpha,
        };
        rec.progress = Some(progress);
        let name = rec.name.clone();
        self.emit_locked(JobEvent::Progress { id, name, progress });
        drop(st);
        self.flush_events();
    }

    pub fn status(&self, id: JobId) -> Option<JobView> {
        let st = self.inner.st.lock().unwrap();
        st.jobs.get(&id).map(|r| view_of(id, r))
    }

    /// All known jobs, id-ordered.
    pub fn jobs(&self) -> Vec<JobView> {
        let st = self.inner.st.lock().unwrap();
        st.jobs.iter().map(|(id, r)| view_of(*id, r)).collect()
    }

    /// Full report for a terminal job (daemon-side consumers: BatchService).
    pub fn full_report(&self, id: JobId) -> Option<RunReport> {
        let st = self.inner.st.lock().unwrap();
        st.jobs.get(&id).and_then(|r| r.report.clone())
    }

    /// Workers report their cumulative operator-cache counters here after
    /// each job; `stats` sums across workers.
    pub fn report_cache(&self, worker: usize, compiles: u64, hits: u64) {
        let mut st = self.inner.st.lock().unwrap();
        st.worker_cache.insert(worker, (compiles, hits));
    }

    pub fn stats(&self) -> ServeStats {
        let st = self.inner.st.lock().unwrap();
        let (compiles, hits) = st
            .worker_cache
            .values()
            .fold((0, 0), |(c, h), &(wc, wh)| (c + wc, h + wh));
        ServeStats {
            submitted: st.counters.submitted,
            queued: st.queued,
            running: st.running,
            completed: st.counters.completed,
            failed: st.counters.failed,
            cancelled: st.counters.cancelled,
            rejected: st.counters.rejected,
            prior_completed: st.counters.prior_completed,
            workers: self.inner.workers,
            cache_compiles: compiles,
            cache_hits: hits,
            store: StoreStats::default(),
            nodes: Vec::new(),
            batches: st.counters.batches,
            coalesced: st.counters.coalesced,
        }
    }

    /// Begin shutdown. `drain = true` finishes queued work first.
    pub fn shutdown(&self, drain: bool) {
        let mut st = self.inner.st.lock().unwrap();
        let mode = if drain { ShutdownMode::Drain } else { ShutdownMode::Now };
        // Never downgrade Now back to Drain.
        if st.shutdown != ShutdownMode::Now {
            st.shutdown = mode;
        }
        drop(st);
        self.inner.cv.notify_all();
    }

    pub fn is_shutting_down(&self) -> bool {
        self.inner.st.lock().unwrap().shutdown != ShutdownMode::Open
    }

    /// True once every submitted job is terminal.
    pub fn idle(&self) -> bool {
        let st = self.inner.st.lock().unwrap();
        st.running == 0 && st.queued == 0
    }
}

/// The scheduler's `SolveObserver`: forwards each iteration of job `id`
/// into the shared state + event bus.
struct ProgressSink {
    sched: Scheduler,
    id: JobId,
}

impl SolveObserver for ProgressSink {
    fn on_iteration(&self, ev: &IterEvent<'_>) {
        self.sched.note_progress(self.id, ev);
    }
}

/// Insert one token into the bounded admission map (oldest-first eviction
/// at the scheduler's retention bound, mirroring terminal-record eviction).
fn note_dedup(st: &mut State, token: &str, id: JobId, retention: usize) {
    st.dedup.insert(token.to_string(), id);
    st.dedup_order.push_back(token.to_string());
    while st.dedup_order.len() > retention {
        if let Some(old) = st.dedup_order.pop_front() {
            st.dedup.remove(&old);
        }
    }
}

fn view_of(id: JobId, r: &JobRecord) -> JobView {
    JobView {
        id,
        name: r.name.clone(),
        priority: r.priority,
        state: r.state,
        iters_done: r.progress.map(|p| p.iters_done),
        grad_rel: r.progress.map(|p| p.grad_rel),
        dispatch_seq: r.dispatch_seq,
        latency_s: r.latency_s,
        wall_s: r.wall_s,
        mismatch_rel: r.report.as_ref().map(|rep| rep.mismatch_rel),
        iters: r.report.as_ref().map(|rep| rep.iters),
        levels: r.report.as_ref().map(|rep| rep.levels),
        converged: r.report.as_ref().map(|rep| rep.converged),
        error: r.error.clone(),
        velocity: r.velocity.clone(),
        warped: r.warped.clone(),
    }
}

// -- Execution backend ------------------------------------------------------

/// What one executed job hands back to the scheduler: the wire-facing
/// report plus store content ids of any retained outputs. Executors
/// without a store attached (stubs, storeless embedders) return a bare
/// report via `From<RunReport>` — `Ok(stub_report("x").into())`.
#[derive(Clone, Debug)]
pub struct ExecOutcome {
    pub report: RunReport,
    /// Content id of the final velocity, when retained in the store.
    pub velocity: Option<String>,
    /// Content id of the warped moving image, when retained.
    pub warped: Option<String>,
}

impl From<RunReport> for ExecOutcome {
    fn from(report: RunReport) -> ExecOutcome {
        ExecOutcome { report, velocity: None, warped: None }
    }
}

/// One worker's job runner. Implementations own whatever per-worker context
/// they need (the real one owns a PJRT client + operator cache; tests use
/// stubs so scheduler/daemon behavior is checkable without artifacts).
pub trait Executor {
    /// Run one job under the scheduler's observer/cancellation context.
    /// Implementations should thread `cx` into the solve
    /// (`Session::solve_cx`) so a running job can be cancelled at
    /// iteration boundaries and report live progress; a stub that ignores
    /// it simply runs uninterruptible, progress-silent jobs.
    fn execute(&mut self, payload: &JobPayload, cx: &SolveCx) -> Result<ExecOutcome>;

    /// Run a coalesced batch, returning one result per member in order.
    /// The default runs members sequentially through `execute`, so stub
    /// executors (and executors with no batched artifacts) keep exact
    /// per-job semantics under a coalescing scheduler; `PjrtExecutor`
    /// overrides this to solve compatible members through one warm batched
    /// executable with per-subject convergence masking.
    fn execute_batch(&mut self, jobs: &[(JobPayload, SolveCx)]) -> Vec<Result<ExecOutcome>> {
        jobs.iter().map(|(payload, cx)| self.execute(payload, cx)).collect()
    }

    /// Give the executor a handle to the daemon's volume store so solve
    /// outputs (velocity, warped image) can be retained server-side for
    /// the `reduce` verb. Default: ignore it — retention is opt-in and
    /// stub executors stay storeless.
    fn attach_store(&mut self, _store: Arc<VolumeStore>) {}

    /// Cumulative (compiles, warm hits) of this worker's operator cache.
    fn cache_stats(&self) -> (u64, u64) {
        (0, 0)
    }
}

/// Production executor: per-worker PJRT client and shared-warm operator
/// cache keyed by `(op, variant, n, precision)` — compilation cost is paid once per
/// worker process lifetime, not once per request.
pub struct PjrtExecutor {
    registry: OpRegistry,
    /// Attached by the daemon at worker spawn; when present, solve
    /// outputs are retained as content-addressed store entries.
    store: Option<Arc<VolumeStore>>,
}

impl PjrtExecutor {
    pub fn open(artifacts_dir: &Path) -> Result<PjrtExecutor> {
        Ok(PjrtExecutor { registry: OpRegistry::open(artifacts_dir)?, store: None })
    }

    /// Materialize a payload into the problem + validated params + warm
    /// start a solve needs (shared by the single and batched execute
    /// paths).
    fn resolve(
        &self,
        payload: &JobPayload,
    ) -> Result<(RegProblem, RegParams, Option<Arc<VecField3>>)> {
        Ok(match payload {
            JobPayload::Spec(spec) => (
                crate::data::synth::nirep_analog_pair(&self.registry, spec.n, &spec.subject)?,
                spec.validate()?,
                None,
            ),
            // `RegProblem` owns its fields, so executing an uploaded job
            // copies both volumes once. That is bounded by the worker
            // count (not the queue) and is noise next to the solve itself;
            // the store's sharing still wins where it matters — one
            // resident copy per distinct volume and dedup'd uploads.
            // Making `RegProblem` hold `Arc<Field3>` would ripple through
            // every layer for a per-job memcpy.
            JobPayload::Volumes { spec, m0, m1, warm_start } => (
                RegProblem::new(spec.name(), (**m0).clone(), (**m1).clone()),
                spec.validate()?,
                warm_start.clone(),
            ),
            JobPayload::Problem { problem, params } => (problem.clone(), params.clone(), None),
        })
    }

    /// Retain a finished solve's outputs in the attached store: the final
    /// velocity always, the warped image m0 ∘ φ⁻¹ when the transport op
    /// lowers for this grid/variant. Best-effort by design — retention
    /// failures (budget, missing op) must never fail a solved job, they
    /// only cost the `reduce` verb a resolvable id.
    fn retain(
        &self,
        solver: &GaussNewtonKrylov,
        problem: &RegProblem,
        res: &crate::registration::solver::RegResult,
    ) -> (Option<String>, Option<String>) {
        let Some(store) = &self.store else { return (None, None) };
        let velocity = store.put_vec(res.v.n, res.v.data.clone()).ok().map(|r| r.id);
        let warped = solver
            .transport(&res.v, &problem.m0.data)
            .and_then(|data| store.put(problem.m0.n, data))
            .ok()
            .map(|r| r.id);
        (velocity, warped)
    }
}

impl Executor for PjrtExecutor {
    fn execute(&mut self, payload: &JobPayload, cx: &SolveCx) -> Result<ExecOutcome> {
        let (problem, params, warm) = self.resolve(payload)?;
        // The unified entry point: `params.algorithm` selects the
        // optimizer (GN-Krylov or a first-order baseline), `multires`
        // picks grid continuation, and the scheduler's context makes the
        // solve observable and cancellable at iteration boundaries.
        let mut session = Session::new(&self.registry).params(params.clone());
        if let Some(ws) = warm {
            session = session.warm_start((*ws).clone());
        }
        let res = session.solve_cx(&problem, cx)?;
        let solver = GaussNewtonKrylov::new(&self.registry, params);
        let (velocity, warped) = self.retain(&solver, &problem, &res);
        let report = RunReport::build(&solver, &problem, &res)?;
        Ok(ExecOutcome { report, velocity, warped })
    }

    /// Coalesced members solve through `Session::solve_batch_cx`: one warm
    /// batched executable evaluates all subjects per iteration with
    /// per-subject convergence masking, falling back to sequential solves
    /// inside the session when no batched artifact fits. A member that
    /// fails to materialize (bad spec, unknown subject) fails alone; the
    /// rest still batch. Warm-started members always take the sequential
    /// path: the batched artifact evaluates all subjects from one zero
    /// initial iterate, and a per-subject seed cannot ride along (the
    /// coalesce key already keeps differently-seeded jobs apart; this
    /// guards the same-seed fusion case).
    fn execute_batch(&mut self, jobs: &[(JobPayload, SolveCx)]) -> Vec<Result<ExecOutcome>> {
        let any_warm = jobs
            .iter()
            .any(|(p, _)| matches!(p, JobPayload::Volumes { warm_start: Some(_), .. }));
        if jobs.len() < 2 || any_warm {
            return jobs.iter().map(|(payload, cx)| self.execute(payload, cx)).collect();
        }
        let mut out: Vec<Option<Result<ExecOutcome>>> = (0..jobs.len()).map(|_| None).collect();
        let mut probs = Vec::new();
        let mut cxs = Vec::new();
        let mut idxs = Vec::new();
        let mut params: Option<RegParams> = None;
        for (i, (payload, cx)) in jobs.iter().enumerate() {
            match self.resolve(payload) {
                Ok((prob, p, _)) => {
                    // Members share a coalesce key, so their validated
                    // params agree on everything the solver reads.
                    params.get_or_insert(p);
                    probs.push(prob);
                    cxs.push(cx.clone());
                    idxs.push(i);
                }
                Err(e) => out[i] = Some(Err(e)),
            }
        }
        if let Some(params) = params {
            let prob_refs: Vec<&RegProblem> = probs.iter().collect();
            let solver = GaussNewtonKrylov::new(&self.registry, params.clone());
            match Session::new(&self.registry).params(params).solve_batch_cx(&prob_refs, &cxs) {
                Ok(results) => {
                    for ((&i, prob), res) in idxs.iter().zip(probs.iter()).zip(results) {
                        out[i] = Some(res.and_then(|r| {
                            let (velocity, warped) = self.retain(&solver, prob, &r);
                            let report = RunReport::build(&solver, prob, &r)?;
                            Ok(ExecOutcome { report, velocity, warped })
                        }));
                    }
                }
                Err(e) => {
                    // Shared machinery failed before any subject solved.
                    let msg = e.to_string();
                    for &i in &idxs {
                        out[i] = Some(Err(Error::Serve(msg.clone())));
                    }
                }
            }
        }
        out.into_iter().map(|o| o.expect("every batch member has a result")).collect()
    }

    fn attach_store(&mut self, store: Arc<VolumeStore>) {
        self.store = Some(store);
    }

    fn cache_stats(&self) -> (u64, u64) {
        (self.registry.cache_compiles(), self.registry.cache_hits())
    }
}

/// Executor used when a worker's context failed to initialize (e.g. no
/// artifacts directory): every job fails cleanly with the init error, and
/// the rest of the pool keeps serving.
pub struct FailingExecutor {
    pub msg: String,
}

impl Executor for FailingExecutor {
    fn execute(&mut self, _payload: &JobPayload, _cx: &SolveCx) -> Result<ExecOutcome> {
        Err(Error::Serve(self.msg.clone()))
    }
}

/// Run jobs until the scheduler says stop. This is the whole worker.
///
/// Dispatch is batch-at-a-time (`next_batch`; a singleton batch when
/// coalescing is off or nothing matched), but completion stays per-job:
/// every member gets its own `complete` with its own result, so job
/// lifecycles are indistinguishable from sequential dispatch. `wall_s` is
/// the shared batch wall time — what each subject actually waited on the
/// worker.
///
/// Executor panics are contained: every job in the dispatched batch is
/// marked `Failed` and the worker keeps serving — otherwise one buggy
/// solve would strand jobs in `Running` forever (never completed,
/// `idle()` never true) and silently shrink the pool.
pub fn worker_loop<E: Executor + ?Sized>(sched: &Scheduler, worker: usize, exec: &mut E) {
    while let Some(batch) = sched.next_batch(worker) {
        let ids: Vec<JobId> = batch.iter().map(|(id, _)| *id).collect();
        let jobs: Vec<(JobPayload, SolveCx)> =
            batch.into_iter().map(|(id, payload)| (payload, sched.solve_cx(id))).collect();
        let t0 = Instant::now();
        let results =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| exec.execute_batch(&jobs)));
        let wall = t0.elapsed().as_secs_f64();
        let (compiles, hits) = exec.cache_stats();
        sched.report_cache(worker, compiles, hits);
        match results {
            Ok(results) => {
                debug_assert_eq!(results.len(), ids.len());
                for (id, result) in ids.iter().zip(results) {
                    sched.complete(*id, result, wall);
                }
            }
            Err(p) => {
                let msg = p
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| p.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "unknown panic payload".into());
                for id in &ids {
                    sched.complete(
                        *id,
                        Err(Error::Serve(format!("job panicked in executor: {msg}"))),
                        wall,
                    );
                }
            }
        }
    }
}

/// Synthetic `IterRecord` for stub executors exercising the progress /
/// cooperative-cancellation paths without compiled artifacts. Finite,
/// monotone-ish values so wire encodings stay well-formed.
pub fn stub_iter(i: usize) -> IterRecord {
    IterRecord {
        level_beta: 5e-4,
        j: 1.0 / (i + 1) as f64,
        mismatch_rel: 0.5,
        grad_rel: 1.0 / (i + 1) as f64,
        cg_iters: 2,
        alpha: 1.0,
        grad_precision: crate::precision::Precision::Full,
        matvec_precision: crate::precision::Precision::Full,
    }
}

/// Synthetic `RunReport` for stub executors in tests and benches (the
/// scheduler does not inspect report contents).
pub fn stub_report(name: &str) -> RunReport {
    RunReport {
        dataset: name.to_string(),
        variant: "stub".into(),
        precision: crate::precision::Precision::Full,
        n: 16,
        detf: crate::math::stats::Summary { min: 1.0, mean: 1.0, max: 1.0 },
        nondiffeo_frac: 0.0,
        dice_before: None,
        dice_after: None,
        mismatch_rel: 0.1,
        grad_rel: 0.01,
        iters: 1,
        matvecs: 1,
        levels: 1,
        time_s: 0.0,
        converged: true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::sync::thread;

    struct Recording {
        ran: Vec<String>,
    }

    impl Executor for Recording {
        fn execute(&mut self, payload: &JobPayload, _cx: &SolveCx) -> Result<ExecOutcome> {
            let name = payload.name();
            self.ran.push(name.clone());
            if name.contains("poison") {
                return Err(Error::Serve("injected failure".into()));
            }
            Ok(stub_report(&name).into())
        }

        fn cache_stats(&self) -> (u64, u64) {
            (3, self.ran.len().saturating_sub(1) as u64 * 3)
        }
    }

    fn spec(subject: &str, priority: Priority) -> JobPayload {
        JobPayload::Spec(JobSpec { subject: subject.into(), priority, ..Default::default() })
    }

    #[test]
    fn priorities_jump_the_queue() {
        let sched = Scheduler::new(64, 1);
        let b1 = sched.submit(Priority::Batch, spec("b1", Priority::Batch)).unwrap();
        let b2 = sched.submit(Priority::Batch, spec("b2", Priority::Batch)).unwrap();
        let e1 = sched.submit(Priority::Emergency, spec("e1", Priority::Emergency)).unwrap();
        let u1 = sched.submit(Priority::Urgent, spec("u1", Priority::Urgent)).unwrap();
        sched.shutdown(true);
        let mut order = Vec::new();
        while let Some((id, _)) = sched.next_job(0) {
            order.push(id);
            sched.complete(id, Ok(stub_report("x").into()), 0.0);
        }
        assert_eq!(order, vec![e1, u1, b1, b2]);
    }

    #[test]
    fn fifo_within_priority_band() {
        let sched = Scheduler::new(64, 1);
        let ids: Vec<JobId> = (0..5)
            .map(|i| {
                sched.submit(Priority::Batch, spec(&format!("j{i}"), Priority::Batch)).unwrap()
            })
            .collect();
        sched.shutdown(true);
        let mut order = Vec::new();
        while let Some((id, _)) = sched.next_job(0) {
            order.push(id);
            sched.complete(id, Ok(stub_report("x").into()), 0.0);
        }
        assert_eq!(order, ids, "same-priority jobs drain in submission order");
    }

    #[test]
    fn bounded_queue_rejects_batch_admits_emergency() {
        let sched = Scheduler::new(2, 1);
        sched.submit(Priority::Batch, spec("a", Priority::Batch)).unwrap();
        sched.submit(Priority::Batch, spec("b", Priority::Batch)).unwrap();
        let rejected = sched.submit(Priority::Batch, spec("c", Priority::Batch));
        assert!(rejected.is_err(), "third batch job must hit admission control");
        assert!(rejected.unwrap_err().to_string().contains("queue full"));
        // Emergency bypasses the bound.
        sched.submit(Priority::Emergency, spec("e", Priority::Emergency)).unwrap();
        let s = sched.stats();
        assert_eq!(s.rejected, 1);
        assert_eq!(s.queued, 3);
    }

    #[test]
    fn cancelled_jobs_release_admission_slots_immediately() {
        let sched = Scheduler::new(2, 1);
        let a = sched.submit(Priority::Batch, spec("a", Priority::Batch)).unwrap();
        let b = sched.submit(Priority::Batch, spec("b", Priority::Batch)).unwrap();
        assert!(sched.submit(Priority::Batch, spec("c", Priority::Batch)).is_err());
        sched.cancel(a).unwrap();
        sched.cancel(b).unwrap();
        // Stale heap entries remain, but the slots must free right away.
        let c = sched.submit(Priority::Batch, spec("c", Priority::Batch)).unwrap();
        assert_eq!(sched.stats().queued, 1);
        sched.shutdown(true);
        let mut order = Vec::new();
        while let Some((id, _)) = sched.next_job(0) {
            order.push(id);
            sched.complete(id, Ok(stub_report("x").into()), 0.0);
        }
        assert_eq!(order, vec![c]);
    }

    #[test]
    fn queued_emergencies_do_not_consume_batch_slots() {
        let sched = Scheduler::new(2, 1);
        for i in 0..5 {
            sched
                .submit(Priority::Emergency, spec(&format!("e{i}"), Priority::Emergency))
                .unwrap();
        }
        // Five queued emergencies, yet both batch slots are still free.
        sched.submit(Priority::Batch, spec("b1", Priority::Batch)).unwrap();
        sched.submit(Priority::Batch, spec("b2", Priority::Batch)).unwrap();
        assert!(sched.submit(Priority::Batch, spec("b3", Priority::Batch)).is_err());
        assert_eq!(sched.stats().queued, 7);
    }

    #[test]
    fn terminal_records_are_evicted_beyond_retention() {
        // queue_cap 1 -> retention floor of 1024 terminal records.
        let sched = Scheduler::new(1, 1);
        let total = 1100u64;
        for i in 0..total {
            let id =
                sched.submit(Priority::Batch, spec(&format!("j{i}"), Priority::Batch)).unwrap();
            let (got, _) = sched.next_job(0).unwrap();
            assert_eq!(got, id);
            sched.complete(id, Ok(stub_report("x").into()), 0.0);
        }
        let views = sched.jobs();
        assert_eq!(views.len(), 1024, "history bounded at retention");
        // Oldest records evicted, newest kept; counters still see all work.
        assert!(sched.status(1).is_none());
        assert!(sched.status(total).is_some());
        assert_eq!(sched.stats().completed, total);
    }

    #[test]
    fn stale_heap_entry_for_evicted_record_is_skipped_not_panic() {
        // A cancelled job's QEntry can stay buried in the heap (under
        // higher-priority traffic) until retention evicts its record;
        // popping the stale entry must skip, not panic.
        let sched = Scheduler::new(1, 1);
        let x = sched.submit(Priority::Batch, spec("x", Priority::Batch)).unwrap();
        sched.cancel(x).unwrap();
        for i in 0..1100u64 {
            let id = sched
                .submit(Priority::Emergency, spec(&format!("e{i}"), Priority::Emergency))
                .unwrap();
            let (got, _) = sched.next_job(0).unwrap();
            assert_eq!(got, id, "emergencies pop before the stale batch entry");
            sched.complete(id, Ok(stub_report("e").into()), 0.0);
        }
        assert!(sched.status(x).is_none(), "cancelled record evicted by retention");
        sched.shutdown(true);
        assert!(sched.next_job(0).is_none(), "stale entry skipped cleanly");
    }

    /// Cooperative executor: iterates up to the job's own `max_iter`
    /// budget, notifying the context each step and honoring cancellation
    /// at the boundary — the stub analog of what `Session::solve_cx` does
    /// inside `PjrtExecutor`.
    struct Cooperative {
        step_ms: u64,
    }

    impl Executor for Cooperative {
        fn execute(&mut self, payload: &JobPayload, cx: &SolveCx) -> Result<ExecOutcome> {
            let iters = match payload {
                JobPayload::Spec(s) | JobPayload::Volumes { spec: s, .. } => {
                    s.max_iter.unwrap_or(1)
                }
                JobPayload::Problem { params, .. } => params.max_iter,
            };
            let mut history = Vec::new();
            for i in 0..iters {
                if cx.cancelled() {
                    return Err(Error::Cancelled { history });
                }
                let rec = stub_iter(i);
                cx.notify(i, &rec);
                history.push(rec);
                thread::sleep(std::time::Duration::from_millis(self.step_ms));
            }
            Ok(stub_report(&payload.name()).into())
        }
    }

    #[test]
    fn cancel_running_job_interrupts_at_iteration_boundary() {
        let sched = Scheduler::new(8, 1);
        let watch = sched.watch();
        let long = JobPayload::Spec(JobSpec {
            subject: "longjob".into(),
            max_iter: Some(10_000), // ~20 s unless the cancel interrupts it
            ..Default::default()
        });
        let short = JobPayload::Spec(JobSpec {
            subject: "next".into(),
            max_iter: Some(3),
            ..Default::default()
        });
        let a = sched.submit(Priority::Batch, long).unwrap();
        let b = sched.submit(Priority::Batch, short).unwrap();
        sched.shutdown(true);
        let worker = {
            let sched = sched.clone();
            thread::spawn(move || {
                let mut exec = Cooperative { step_ms: 2 };
                worker_loop(&sched, 0, &mut exec);
            })
        };
        // Wait until the first job is running and has made progress.
        let t0 = Instant::now();
        loop {
            let v = sched.status(a).unwrap();
            if v.state == JobState::Running && v.iters_done.unwrap_or(0) >= 2 {
                break;
            }
            assert!(t0.elapsed().as_secs() < 10, "job never progressed: {v:?}");
            thread::sleep(std::time::Duration::from_millis(2));
        }
        // Cancel the *running* job: accepted, and the solve stops at the
        // next iteration boundary with its partial history preserved.
        sched.cancel(a).unwrap();
        worker.join().unwrap();
        let v = sched.status(a).unwrap();
        assert_eq!(v.state, JobState::Cancelled, "running → cancelled");
        assert!(v.iters_done.unwrap() >= 2, "partial history visible: {v:?}");
        assert!(v.grad_rel.is_some(), "latest grad_rel visible");
        assert!(v.wall_s.is_some(), "terminal timing recorded");
        assert!(v.error.is_none(), "cancellation is not a failure");
        // The worker went straight on to the next job; both cancelled jobs
        // count once in stats.
        assert_eq!(sched.status(b).unwrap().state, JobState::Done);
        let s = sched.stats();
        assert_eq!(s.cancelled, 1);
        assert_eq!(s.completed, 1);
        assert_eq!(s.failed, 0);
        // The watch stream saw progress beats while running, then the
        // terminal cancelled transition (never a `failed`).
        let mut saw_progress = 0usize;
        let mut terminal = None;
        while let Some(BusMsg::Event(ev)) = watch.recv() {
            if ev.id != a {
                continue;
            }
            if ev.progress.is_some() {
                saw_progress += 1;
                assert_eq!(ev.state, JobState::Running);
            }
            if ev.state == JobState::Cancelled {
                terminal = Some(ev);
                break;
            }
            assert_ne!(ev.state, JobState::Failed);
        }
        assert!(saw_progress >= 2, "progress events streamed");
        let terminal = terminal.expect("cancelled transition streamed");
        assert!(terminal.wall_s.is_some());
        sched.unwatch(watch.id());
    }

    #[test]
    fn cancel_flag_losing_the_race_keeps_done() {
        // Cancel lands while running but the executor finishes without
        // reaching another boundary: the job completes `done` — the flag
        // is a request, not preemption — and nothing double-counts.
        let sched = Scheduler::new(4, 1);
        let a = sched.submit(Priority::Batch, spec("a", Priority::Batch)).unwrap();
        let (id, payload) = sched.next_job(0).unwrap();
        assert_eq!(id, a);
        sched.cancel(a).unwrap(); // running: accepted as a request
        // Executor never checks the flag again and completes normally.
        sched.complete(id, Ok(stub_report(&payload.name()).into()), 0.0);
        assert_eq!(sched.status(a).unwrap().state, JobState::Done);
        let s = sched.stats();
        assert_eq!(s.completed, 1);
        assert_eq!(s.cancelled, 0);
    }

    #[test]
    fn cancel_queued_only() {
        let sched = Scheduler::new(64, 1);
        let a = sched.submit(Priority::Batch, spec("a", Priority::Batch)).unwrap();
        let b = sched.submit(Priority::Batch, spec("b", Priority::Batch)).unwrap();
        sched.cancel(b).unwrap();
        assert_eq!(sched.status(b).unwrap().state, JobState::Cancelled);
        assert!(sched.cancel(b).is_err(), "cancel is not idempotent on terminal jobs");
        assert!(sched.cancel(999).is_err());
        sched.shutdown(true);
        let mut order = Vec::new();
        while let Some((id, _)) = sched.next_job(0) {
            order.push(id);
            sched.complete(id, Ok(stub_report("x").into()), 0.0);
        }
        assert_eq!(order, vec![a], "cancelled job is never dispatched");
        assert_eq!(sched.status(b).unwrap().dispatch_seq, None);
        assert_eq!(sched.stats().cancelled, 1);
    }

    #[test]
    fn worker_loop_drains_and_reports() {
        let sched = Scheduler::new(64, 2);
        for i in 0..6 {
            sched.submit(Priority::Batch, spec(&format!("j{i}"), Priority::Batch)).unwrap();
        }
        let poisoned = sched.submit(Priority::Batch, spec("poison", Priority::Batch)).unwrap();
        sched.shutdown(true);
        thread::scope(|s| {
            for w in 0..2 {
                let sched = sched.clone();
                s.spawn(move || {
                    let mut exec = Recording { ran: Vec::new() };
                    worker_loop(&sched, w, &mut exec);
                });
            }
        });
        let stats = sched.stats();
        assert_eq!(stats.completed, 6);
        assert_eq!(stats.failed, 1, "poisoned job fails without taking the pool down");
        assert_eq!(sched.status(poisoned).unwrap().state, JobState::Failed);
        assert!(sched.status(poisoned).unwrap().error.is_some());
        assert!(stats.cache_hits > 0, "warm cache reuse across same-size jobs");
        assert!(sched.idle());
        // Every non-cancelled job has latency >= wall time.
        for v in sched.jobs() {
            let (Some(lat), Some(wall)) = (v.latency_s, v.wall_s) else {
                panic!("terminal job missing timing: {v:?}");
            };
            assert!(lat + 1e-9 >= wall, "{lat} < {wall}");
        }
    }

    #[test]
    fn panicking_executor_fails_job_and_worker_survives() {
        struct Panicky;
        impl Executor for Panicky {
            fn execute(&mut self, payload: &JobPayload, _cx: &SolveCx) -> Result<ExecOutcome> {
                if payload.name().contains("boom") {
                    panic!("solver exploded");
                }
                Ok(stub_report(&payload.name()).into())
            }
        }
        let sched = Scheduler::new(8, 1);
        let bad = sched.submit(Priority::Batch, spec("boom", Priority::Batch)).unwrap();
        let good = sched.submit(Priority::Batch, spec("fine", Priority::Batch)).unwrap();
        sched.shutdown(true);
        let mut exec = Panicky;
        worker_loop(&sched, 0, &mut exec);
        let v = sched.status(bad).unwrap();
        assert_eq!(v.state, JobState::Failed);
        assert!(v.error.unwrap().contains("panicked"));
        // The same worker went on to serve the next job.
        assert_eq!(sched.status(good).unwrap().state, JobState::Done);
        assert!(sched.idle());
    }

    /// Records the size of every dispatched batch; members run through
    /// the default sequential `execute` path.
    struct BatchRecording {
        sizes: Arc<Mutex<Vec<usize>>>,
    }

    impl Executor for BatchRecording {
        fn execute(&mut self, payload: &JobPayload, _cx: &SolveCx) -> Result<ExecOutcome> {
            Ok(stub_report(&payload.name()).into())
        }

        fn execute_batch(&mut self, jobs: &[(JobPayload, SolveCx)]) -> Vec<Result<ExecOutcome>> {
            self.sizes.lock().unwrap().push(jobs.len());
            jobs.iter().map(|(p, cx)| self.execute(p, cx)).collect()
        }
    }

    #[test]
    fn compatible_batch_jobs_coalesce_into_one_dispatch() {
        let sched = Scheduler::new(64, 1);
        sched.set_coalesce(8, 0);
        for i in 0..4 {
            sched.submit(Priority::Batch, spec(&format!("s{i}"), Priority::Batch)).unwrap();
        }
        // A different grid size selects a different executable: never fused.
        let odd = JobPayload::Spec(JobSpec { subject: "odd".into(), n: 32, ..Default::default() });
        sched.submit(Priority::Batch, odd).unwrap();
        sched.shutdown(true);
        let sizes = Arc::new(Mutex::new(Vec::new()));
        let mut exec = BatchRecording { sizes: sizes.clone() };
        worker_loop(&sched, 0, &mut exec);
        assert_eq!(*sizes.lock().unwrap(), vec![4, 1]);
        let s = sched.stats();
        assert_eq!(s.completed, 5, "every member completes individually");
        assert_eq!(s.batches, 1, "one coalesced dispatch");
        assert_eq!(s.coalesced, 4, "four member jobs");
        // Each member carries its own dispatch bookkeeping.
        let mut seqs: Vec<u64> = sched.jobs().iter().filter_map(|v| v.dispatch_seq).collect();
        seqs.sort_unstable();
        assert_eq!(seqs, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn urgent_jobs_and_disabled_coalescing_dispatch_singletons() {
        let sched = Scheduler::new(64, 1);
        sched.set_coalesce(8, 0);
        for i in 0..3 {
            sched.submit(Priority::Urgent, spec(&format!("u{i}"), Priority::Urgent)).unwrap();
        }
        sched.shutdown(true);
        let sizes = Arc::new(Mutex::new(Vec::new()));
        let mut exec = BatchRecording { sizes: sizes.clone() };
        worker_loop(&sched, 0, &mut exec);
        assert_eq!(*sizes.lock().unwrap(), vec![1, 1, 1], "urgent never coalesces");
        assert_eq!(sched.stats().batches, 0);
        // With coalescing off (the default), batch jobs also go one at a time.
        let sched = Scheduler::new(64, 1);
        for i in 0..3 {
            sched.submit(Priority::Batch, spec(&format!("b{i}"), Priority::Batch)).unwrap();
        }
        sched.shutdown(true);
        let sizes = Arc::new(Mutex::new(Vec::new()));
        let mut exec = BatchRecording { sizes: sizes.clone() };
        worker_loop(&sched, 0, &mut exec);
        assert_eq!(*sizes.lock().unwrap(), vec![1, 1, 1]);
        assert_eq!(sched.stats().coalesced, 0);
    }

    #[test]
    fn dwell_window_catches_late_compatible_arrivals() {
        let sched = Scheduler::new(64, 1);
        sched.set_coalesce(2, 2_000);
        let sizes = Arc::new(Mutex::new(Vec::new()));
        let worker = {
            let sched = sched.clone();
            let sizes = sizes.clone();
            thread::spawn(move || {
                let mut exec = BatchRecording { sizes };
                worker_loop(&sched, 0, &mut exec);
            })
        };
        let a = sched.submit(Priority::Batch, spec("a", Priority::Batch)).unwrap();
        // Wait until the worker holds `a` as a dwelling batch leader...
        let t0 = Instant::now();
        while sched.status(a).unwrap().state != JobState::Running {
            assert!(t0.elapsed().as_secs() < 10, "leader never dispatched");
            thread::sleep(Duration::from_millis(1));
        }
        // ... then a compatible arrival joins it instead of waiting behind it.
        let b = sched.submit(Priority::Batch, spec("b", Priority::Batch)).unwrap();
        let t0 = Instant::now();
        while !sched.idle() {
            assert!(t0.elapsed().as_secs() < 10, "batch never completed");
            thread::sleep(Duration::from_millis(1));
        }
        sched.shutdown(true);
        worker.join().unwrap();
        assert_eq!(*sizes.lock().unwrap(), vec![2], "late arrival coalesced into the dwell");
        assert_eq!(sched.status(b).unwrap().state, JobState::Done);
        let s = sched.stats();
        assert_eq!((s.batches, s.coalesced), (1, 2));
    }

    #[test]
    fn dedup_resubmission_returns_original_id() {
        let sched = Scheduler::new(64, 1);
        let a = sched
            .submit_dedup(Priority::Batch, spec("a", Priority::Batch), Some("tok-1".into()))
            .unwrap();
        let again = sched
            .submit_dedup(Priority::Batch, spec("a", Priority::Batch), Some("tok-1".into()))
            .unwrap();
        assert_eq!(a, again, "resubmit with the same token is the same job");
        assert_eq!(sched.stats().submitted, 1, "no duplicate admission");
        assert_eq!(sched.stats().queued, 1);
        let b = sched
            .submit_dedup(Priority::Batch, spec("b", Priority::Batch), Some("tok-2".into()))
            .unwrap();
        assert_ne!(a, b, "distinct tokens admit distinct jobs");
        // The token survives the job reaching a terminal state...
        let (id, _) = sched.next_job(0).unwrap();
        sched.complete(id, Ok(stub_report("a").into()), 0.0);
        assert_eq!(
            sched
                .submit_dedup(Priority::Batch, spec("a", Priority::Batch), Some("tok-1".into()))
                .unwrap(),
            a,
            "retry after completion still returns the original id"
        );
        // ... and beats the queue bound: a retry of admitted work must not
        // get a busy signal.
        let tight = Scheduler::new(1, 1);
        let x = tight
            .submit_dedup(Priority::Batch, spec("x", Priority::Batch), Some("t".into()))
            .unwrap();
        assert!(tight.submit(Priority::Batch, spec("y", Priority::Batch)).is_err());
        assert_eq!(
            tight
                .submit_dedup(Priority::Batch, spec("x", Priority::Batch), Some("t".into()))
                .unwrap(),
            x
        );
        // Journal-replayed tokens reseed the map across restarts.
        tight.seed_dedup("replayed", 7);
        assert_eq!(
            tight
                .submit_dedup(Priority::Batch, spec("z", Priority::Batch), Some("replayed".into()))
                .unwrap(),
            7
        );
    }

    #[test]
    fn submit_after_shutdown_is_rejected() {
        let sched = Scheduler::new(4, 1);
        sched.shutdown(true);
        assert!(sched.submit(Priority::Emergency, spec("late", Priority::Emergency)).is_err());
    }

    #[test]
    fn event_sink_sees_lifecycle() {
        let events: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));
        let sched = Scheduler::new(8, 1);
        let ev2 = events.clone();
        sched.set_event_sink(Box::new(move |ev| {
            let tag = match ev {
                JobEvent::Submitted { .. } => "submitted",
                JobEvent::Started { .. } => "started",
                JobEvent::Progress { .. } => "progress",
                JobEvent::Finished { state, .. } => state.as_str(),
                JobEvent::Cancelled { .. } => "cancelled",
            };
            ev2.lock().unwrap().push(tag.to_string());
        }));
        let a = sched.submit(Priority::Batch, spec("a", Priority::Batch)).unwrap();
        let b = sched.submit(Priority::Batch, spec("b", Priority::Batch)).unwrap();
        sched.cancel(b).unwrap();
        sched.shutdown(true);
        let (id, _) = sched.next_job(0).unwrap();
        assert_eq!(id, a);
        sched.complete(id, Ok(stub_report("a").into()), 0.0);
        assert_eq!(
            *events.lock().unwrap(),
            vec!["submitted", "submitted", "cancelled", "started", "done"]
        );
    }

    /// Drain one subscriber's currently-visible messages into state tags.
    fn drain_states(h: &WatchHandle, expect: usize) -> Vec<String> {
        let mut out = Vec::new();
        for _ in 0..expect {
            match h.recv() {
                Some(BusMsg::Event(ev)) => out.push(ev.state.as_str().to_string()),
                Some(BusMsg::Lagged) => out.push("lagged".into()),
                None => break,
            }
        }
        out
    }

    #[test]
    fn watch_subscribers_see_full_lifecycle_in_order() {
        let sched = Scheduler::new(8, 1);
        let h = sched.watch();
        let a = sched.submit(Priority::Batch, spec("a", Priority::Batch)).unwrap();
        let b = sched.submit(Priority::Batch, spec("b", Priority::Batch)).unwrap();
        sched.cancel(b).unwrap();
        let (id, _) = sched.next_job(0).unwrap();
        assert_eq!(id, a);
        sched.complete(id, Err(Error::Serve("boom".into())), 0.5);
        let states = drain_states(&h, 5);
        assert_eq!(states, vec!["queued", "queued", "cancelled", "running", "failed"]);
        // Terminal events carry timing + failure detail.
        let h2 = sched.watch();
        let c = sched.submit(Priority::Batch, spec("c", Priority::Batch)).unwrap();
        let (got, _) = sched.next_job(0).unwrap();
        assert_eq!(got, c);
        sched.complete(c, Ok(stub_report("c").into()), 0.25);
        let mut last = None;
        for _ in 0..3 {
            if let Some(BusMsg::Event(ev)) = h2.recv() {
                last = Some(ev);
            }
        }
        let last = last.unwrap();
        assert_eq!(last.state, JobState::Done);
        assert_eq!(last.wall_s, Some(0.25));
        assert_eq!(last.error, None);
        sched.unwatch(h.id());
        sched.unwatch(h2.id());
        assert!(h.recv().is_none(), "unwatched handle sees end of stream");
    }

    #[test]
    fn slow_subscriber_is_dropped_with_terminal_lagged_marker() {
        let sched = Scheduler::new(64, 1);
        let slow = sched.watch_with_cap(2);
        let fast = sched.watch();
        // 4 submissions = 4 queued events; the slow queue holds 2 + the
        // lagged marker, the fast one sees all 4.
        for i in 0..4 {
            sched.submit(Priority::Batch, spec(&format!("j{i}"), Priority::Batch)).unwrap();
        }
        let states = drain_states(&slow, 4);
        assert_eq!(states, vec!["queued", "queued", "lagged"]);
        assert!(slow.recv().is_none(), "lagged stream is terminal");
        // The publisher already forgot the lagged subscriber (so a
        // connection can re-subscribe); the healthy one is still live.
        assert!(!sched.is_watching(slow.id()));
        assert!(sched.is_watching(fast.id()));
        assert_eq!(drain_states(&fast, 4), vec!["queued"; 4]);
        // The lagged subscriber no longer costs the publisher anything:
        // further events are delivered to survivors only.
        sched.submit(Priority::Batch, spec("late", Priority::Batch)).unwrap();
        assert_eq!(drain_states(&fast, 1), vec!["queued"]);
        sched.unwatch(fast.id());
    }

    #[test]
    fn watch_never_blocks_submitters() {
        // A subscriber that never drains must not wedge submit/complete:
        // the queue flips lagged and the workload proceeds.
        let sched = Scheduler::new(64, 1);
        let _stuck = sched.watch_with_cap(1);
        for i in 0..16 {
            let id =
                sched.submit(Priority::Batch, spec(&format!("j{i}"), Priority::Batch)).unwrap();
            let (got, _) = sched.next_job(0).unwrap();
            assert_eq!(got, id);
            sched.complete(id, Ok(stub_report("x").into()), 0.0);
        }
        assert_eq!(sched.stats().completed, 16);
    }
}
