//! Per-verb forwarding: placement-aware upload, affinity-aware submit
//! with backpressure failover, global-id translation for job verbs, and
//! fan-out-and-merge for the federated control plane.

use crate::error::{Error, ErrorCode, Result};
use crate::request::{JobRequest, JobSource};
use crate::serve::proto::{ReduceRequest, Response, PROTO_VERSION};
use crate::serve::router::Fleet;
use crate::serve::scheduler::{JobId, JobView, NodeStats, ServeStats};
use crate::serve::store::content_id;
use crate::util::rng::Rng;
use crate::util::sync::thread;

/// Place an uploaded volume on its ring-chosen holders and forward the
/// payload to each. The router computes the content id itself (same FNV
/// hash the store uses), so placement never depends on a backend round
/// trip. Partial placement succeeds — the volume index records exactly
/// the holders that acknowledged, and a later submit only considers
/// those — but total failure surfaces the last backend error.
pub(crate) fn handle_upload(fleet: &Fleet, n: usize, data: Vec<f32>) -> Result<Response> {
    let id = content_id(n, &data);
    let want = fleet.ring.place(&id, fleet.cfg.replication, |s| fleet.pool.is_up(s));
    if want.is_empty() {
        return Err(Error::wire(
            ErrorCode::Unavailable,
            "no live backend to place the volume on",
        ));
    }
    let mut placed = Vec::new();
    let mut all_dedup = true;
    let mut last_err = None;
    for &slot in &want {
        match fleet.pool.with_client(slot, |c| c.upload_with_retry(n, &data, &fleet.cfg.retry)) {
            Ok(receipt) => {
                debug_assert_eq!(receipt.id, id, "store content hash must match placement key");
                all_dedup &= receipt.dedup;
                placed.push(slot);
            }
            Err(e) => last_err = Some(e),
        }
    }
    if placed.is_empty() {
        return Err(last_err.expect("at least one holder was attempted"));
    }
    fleet.record_volume(&id, n, &placed);
    // Dedup only when *every* holder already had the volume — a partial
    // re-replication still moved bytes.
    Ok(Response::Uploaded { id, n, dedup: all_dedup })
}

/// Candidate slots for a job, best first. Deterministic failures
/// (volumes never routed through this router, pairs that share no
/// holder) are errors; an empty list means "nothing alive right now" and
/// is worth retrying.
fn candidates(fleet: &Fleet, spec: &JobRequest) -> Result<Vec<usize>> {
    match &spec.source {
        JobSource::Uploaded { m0, m1 } => {
            let both: Vec<usize> = {
                let st = fleet.st.lock().unwrap();
                let miss = |id: &str| {
                    Error::wire(
                        ErrorCode::UnknownVolume,
                        format!("unknown volume id '{id}' (not uploaded through this router)"),
                    )
                };
                let h0 = st.volumes.get(m0).ok_or_else(|| miss(m0))?;
                let h1 = st.volumes.get(m1).ok_or_else(|| miss(m1))?;
                let mut both: Vec<usize> =
                    h0.holders.intersection(&h1.holders).copied().collect();
                // A warm-start velocity the router knows about (e.g. a
                // reduce result) narrows the candidates further; ids it
                // never saw (backend-retained outputs) are left to pair
                // affinity, and the backend validates at admission.
                if let Some(ws) = spec.warm_start.as_deref() {
                    if let Some(hw) = st.volumes.get(ws) {
                        both.retain(|s| hw.holders.contains(s));
                    }
                }
                both
            };
            if both.is_empty() {
                return Err(Error::wire(
                    ErrorCode::UnknownVolume,
                    format!(
                        "volumes {m0} and {m1} share no backend; re-upload the pair \
                         (or raise replication so pairs co-locate)"
                    ),
                ));
            }
            // Rank shared holders by ring preference on the *pair* key:
            // repeat submissions of the same pair land on the same node,
            // which keeps its operator caches warm.
            let pref = fleet.ring.place(&format!("{m0}:{m1}"), 0, |s| fleet.pool.is_up(s));
            Ok(pref.into_iter().filter(|s| both.contains(s)).collect())
        }
        JobSource::Synthetic => {
            // No data affinity: least queue pressure first (probe cache),
            // slot index as the deterministic tiebreak.
            let mut alive = fleet.pool.alive();
            alive.sort_by_key(|&s| (fleet.pool.load(s), s));
            Ok(alive)
        }
    }
}

/// Route one job: walk the candidates best-first, failing over on
/// backpressure (`queue_full`, `shutting_down`) and transport loss
/// (`unavailable` from the pool), with jittered backoff between rounds
/// when every candidate refused retryably. Non-retryable rejections
/// (bad request, shape mismatch, unknown volume on the backend) abort
/// immediately — no other node would answer differently. Returns the
/// router-global job id.
pub(crate) fn handle_submit(fleet: &Fleet, spec: &JobRequest) -> Result<JobId> {
    // Validate up front: reject malformed jobs without burning a backend
    // round trip (and without consulting placement state).
    spec.validate()?;
    let policy = fleet.cfg.retry;
    let mut rng = Rng::new(policy.seed ^ fleet.seed_mix());
    let attempts = policy.attempts.max(1);
    let mut last_err: Option<Error> = None;
    for attempt in 1..=attempts {
        // Re-rank every round: health marks and load move under us.
        for slot in candidates(fleet, spec)? {
            match fleet.pool.with_client(slot, |c| c.submit(spec)) {
                Ok(local) => return Ok(fleet.record_route(slot, local)),
                Err(Error::Wire { code, msg }) if code.retryable() => {
                    last_err = Some(Error::Wire { code, msg });
                }
                Err(e @ Error::Wire { .. }) => return Err(e),
                Err(e) => last_err = Some(e),
            }
        }
        if attempt < attempts {
            thread::sleep(policy.backoff(attempt, &mut rng));
        }
    }
    Err(last_err.unwrap_or_else(|| {
        Error::wire(ErrorCode::Unavailable, "no live backend holds this job's volumes")
    }))
}

pub(crate) fn handle_status_one(fleet: &Fleet, global: JobId) -> Result<JobView> {
    let (slot, local) = fleet.route(global)?;
    let mut view = fleet.pool.with_client(slot, |c| c.status(local))?;
    view.id = global;
    Ok(view)
}

pub(crate) fn handle_cancel(fleet: &Fleet, global: JobId) -> Result<()> {
    let (slot, local) = fleet.route(global)?;
    fleet.pool.with_client(slot, |c| c.cancel(local))
}

/// Forward a `reduce`: the reduction runs where the volumes are, so
/// every input must resolve to ONE backend. Jobs mode translates the
/// router-global job ids to backend-local ids and requires every named
/// job to have been routed to the same slot; ids mode picks a common
/// holder from the volume index (ranked by ring preference on the input
/// key so repeat reduces land on the same node). Inputs spanning
/// backends are `invalid_state` — the router does not migrate volumes
/// (documented limitation: raise `replication` so a round's pairs
/// co-locate, or point the template driver at one daemon).
///
/// The result volume lands on that backend's store only; it is recorded
/// in the router's volume index so a later `submit` naming the reduced
/// template resolves.
pub(crate) fn handle_reduce(fleet: &Fleet, req: ReduceRequest) -> Result<Response> {
    let (slot, fwd) = if !req.jobs.is_empty() {
        let mut slot: Option<usize> = None;
        let mut local = Vec::with_capacity(req.jobs.len());
        for &global in &req.jobs {
            let (s, l) = fleet.route(global)?;
            if *slot.get_or_insert(s) != s {
                return Err(Error::wire(
                    ErrorCode::InvalidState,
                    "reduce inputs span backends; the router cannot reduce across \
                     nodes — raise replication so the round's pairs co-locate",
                ));
            }
            local.push(l);
        }
        let mut fwd = req.clone();
        fwd.jobs = local;
        (slot.expect("jobs checked non-empty"), fwd)
    } else {
        // ids mode: every input — and the apply/ref templates, which the
        // backend must also resolve — needs a shared live holder.
        let mut need: Vec<&str> = req.ids.iter().map(String::as_str).collect();
        need.extend(req.apply.as_deref());
        need.extend(req.ref_id.as_deref());
        let common: Vec<usize> = {
            let st = fleet.st.lock().unwrap();
            let mut holders: Option<std::collections::BTreeSet<usize>> = None;
            for id in &need {
                let entry = st.volumes.get(*id).ok_or_else(|| {
                    Error::wire(
                        ErrorCode::UnknownVolume,
                        format!("unknown volume id '{id}' (not uploaded through this router)"),
                    )
                })?;
                holders = Some(match holders {
                    None => entry.holders.clone(),
                    Some(h) => h.intersection(&entry.holders).copied().collect(),
                });
            }
            holders.map(|h| h.into_iter().collect()).unwrap_or_default()
        };
        let key = need.join(":");
        let pref = fleet.ring.place(&key, 0, |s| fleet.pool.is_up(s));
        let Some(slot) = pref.into_iter().find(|s| common.contains(s)) else {
            return Err(Error::wire(
                ErrorCode::InvalidState,
                "reduce inputs share no live backend; re-upload them \
                 (or raise replication so they co-locate)",
            ));
        };
        (slot, req.clone())
    };
    let r = fleet.pool.with_client(slot, |c| c.reduce(&fwd))?;
    fleet.record_volume(&r.id, r.n, &[slot]);
    Ok(Response::Reduced {
        id: r.id,
        n: r.n,
        kind: r.kind,
        count: r.count,
        bytes: r.bytes,
        dedup: r.dedup,
        delta_rel: r.delta_rel,
    })
}

/// Merged job listing: fan out to live backends and translate. Jobs
/// submitted directly to a backend have no global id and are invisible
/// here — the router only speaks for work it placed.
pub(crate) fn handle_jobs(fleet: &Fleet) -> Result<Vec<JobView>> {
    let mut out = Vec::new();
    for slot in fleet.pool.alive() {
        let Ok(views) = fleet.pool.with_client(slot, |c| c.jobs()) else {
            continue; // marked down by the pool; the rest still answer
        };
        let st = fleet.st.lock().unwrap();
        for mut v in views {
            if let Some(&global) = st.reverse.get(&(slot, v.id)) {
                v.id = global;
                out.push(v);
            }
        }
    }
    out.sort_by_key(|v| v.id);
    Ok(out)
}

/// Fleet-wide stats: every counter summed across reachable backends,
/// plus the per-node breakdown (`nodes`) that single daemons leave
/// empty. A node that cannot be reached still gets a row — `up: false`,
/// zero load, its routed count preserved — so operators see the full
/// configured fleet, not just the survivors.
pub(crate) fn handle_stats(fleet: &Fleet) -> ServeStats {
    let mut total = ServeStats::default();
    let mut nodes = Vec::with_capacity(fleet.pool.len());
    for slot in 0..fleet.pool.len() {
        let addr = fleet.pool.addr(slot).to_string();
        let node = fleet.pool.last_probe(slot).map(|p| p.node).unwrap_or_default();
        let routed = fleet.st.lock().unwrap().routed[slot];
        let polled = if fleet.pool.is_up(slot) {
            fleet.pool.with_client(slot, |c| c.stats()).ok()
        } else {
            None
        };
        match polled {
            Some(s) => {
                total.submitted += s.submitted;
                total.queued += s.queued;
                total.running += s.running;
                total.completed += s.completed;
                total.failed += s.failed;
                total.cancelled += s.cancelled;
                total.rejected += s.rejected;
                total.prior_completed += s.prior_completed;
                total.workers += s.workers;
                total.cache_compiles += s.cache_compiles;
                total.cache_hits += s.cache_hits;
                total.store.volumes += s.store.volumes;
                total.store.bytes += s.store.bytes;
                total.store.uploads += s.store.uploads;
                total.store.dedup_hits += s.store.dedup_hits;
                total.store.evictions += s.store.evictions;
                total.store.pinned += s.store.pinned;
                total.batches += s.batches;
                total.coalesced += s.coalesced;
                nodes.push(NodeStats {
                    node,
                    addr,
                    up: true,
                    queued: s.queued,
                    running: s.running,
                    completed: s.completed,
                    routed,
                });
            }
            None => nodes.push(NodeStats {
                node,
                addr,
                up: false,
                queued: 0,
                running: 0,
                completed: 0,
                routed,
            }),
        }
    }
    total.nodes = nodes;
    total
}

/// The router's own ping answer: its identity plus aggregate fleet load
/// from the probe cache — no backend round trips on the ping path.
pub(crate) fn handle_probe(fleet: &Fleet) -> Response {
    let (queued, running) = fleet.pool.fleet_load();
    Response::Pong { node: fleet.node_id.clone(), proto: PROTO_VERSION, queued, running }
}

/// Fan the shutdown out to every backend, best effort — one verb drains
/// the whole fleet. The caller stops the router tier itself afterwards.
pub(crate) fn handle_shutdown(fleet: &Fleet, drain: bool) {
    for slot in 0..fleet.pool.len() {
        let _ = fleet.pool.with_client(slot, |c| c.shutdown(drain));
    }
}
