//! Consistent-hash placement of content ids onto fleet nodes.
//!
//! Classic ring with virtual nodes: each backend owns [`DEFAULT_VNODES`]
//! points on a 64-bit hash circle, and a key is placed on the first
//! distinct nodes found walking clockwise from the key's own hash. Two
//! properties make this the right structure for volume placement:
//!
//! - **Stability**: the owner of a key depends only on the hash circle,
//!   so every router instance (and every restart) computes the same
//!   placement from the same backend list — no coordination needed.
//! - **Minimal disruption**: growing the fleet from N to N+1 nodes only
//!   moves the keys whose nearest point changed, ≈ 1/(N+1) of them,
//!   instead of reshuffling everything the way `hash % N` would.
//!
//! Liveness is layered on top rather than baked into the ring: `place`
//! takes an `alive` predicate and simply skips dead nodes while walking,
//! so a downed backend's keys spill to its ring successors and snap back
//! to the original owners the moment the node is marked up again.

/// Virtual nodes per backend. 64 points keeps the max/min load ratio of
/// a uniform key population within a small constant factor even for tiny
/// fleets, at negligible memory cost (16 bytes per point).
pub const DEFAULT_VNODES: usize = 64;

/// FNV-1a over a byte string (offline build: no external hashers). The
/// same function the content store uses for volume ids, truncated to 64
/// bits — ring placement needs dispersion, not collision resistance.
pub(crate) fn fnv64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// The hash circle: `(point, node)` pairs sorted by point.
#[derive(Clone, Debug)]
pub struct Ring {
    points: Vec<(u64, usize)>,
    nodes: usize,
}

impl Ring {
    /// Build a ring over node indices `0..nodes` with `vnodes` points
    /// each. Point hashes depend only on `(node, vnode)` labels, so a
    /// node keeps its points for life — the minimal-disruption property
    /// follows directly.
    pub fn new(nodes: usize, vnodes: usize) -> Ring {
        let vnodes = vnodes.max(1);
        let mut points = Vec::with_capacity(nodes * vnodes);
        for node in 0..nodes {
            for v in 0..vnodes {
                points.push((fnv64(format!("vnode/{node}/{v}").as_bytes()), node));
            }
        }
        points.sort_unstable();
        Ring { points, nodes }
    }

    pub fn nodes(&self) -> usize {
        self.nodes
    }

    /// Nodes that should hold `key`, in ring preference order.
    ///
    /// `replicas` is the number of distinct nodes wanted; `0` means all
    /// of them (atlas / fixed volumes replicated fleet-wide). Nodes
    /// failing the `alive` predicate are skipped, so placement routes
    /// around downed backends without perturbing the ring itself. The
    /// result can be shorter than requested (or empty) when too few
    /// nodes are alive.
    pub fn place(&self, key: &str, replicas: usize, alive: impl Fn(usize) -> bool) -> Vec<usize> {
        if self.points.is_empty() {
            return Vec::new();
        }
        let want = if replicas == 0 { self.nodes } else { replicas.min(self.nodes) };
        let h = fnv64(key.as_bytes());
        let start = self.points.partition_point(|&(p, _)| p < h) % self.points.len();
        let mut out = Vec::with_capacity(want);
        for i in 0..self.points.len() {
            let (_, node) = self.points[(start + i) % self.points.len()];
            if !out.contains(&node) && alive(node) {
                out.push(node);
                if out.len() == want {
                    break;
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn placement_is_deterministic() {
        let a = Ring::new(5, DEFAULT_VNODES);
        let b = Ring::new(5, DEFAULT_VNODES);
        for key in ["vol-1", "vol-2", "another/key"] {
            assert_eq!(a.place(key, 2, |_| true), b.place(key, 2, |_| true));
        }
    }

    #[test]
    fn replicas_are_distinct_and_capped() {
        let r = Ring::new(3, DEFAULT_VNODES);
        let p = r.place("some-volume", 2, |_| true);
        assert_eq!(p.len(), 2);
        assert_ne!(p[0], p[1]);
        // Asking for more replicas than nodes caps at the fleet size.
        assert_eq!(r.place("some-volume", 10, |_| true).len(), 3);
    }

    #[test]
    fn zero_replicas_means_all_nodes() {
        let r = Ring::new(4, DEFAULT_VNODES);
        let mut p = r.place("atlas", 0, |_| true);
        p.sort_unstable();
        assert_eq!(p, vec![0, 1, 2, 3]);
    }

    #[test]
    fn dead_nodes_are_skipped_and_restored() {
        let r = Ring::new(3, DEFAULT_VNODES);
        let home = r.place("k", 1, |_| true)[0];
        let failover = r.place("k", 1, |n| n != home)[0];
        assert_ne!(failover, home);
        // Mark-up restores the original owner (placement is memoryless).
        assert_eq!(r.place("k", 1, |_| true)[0], home);
    }

    #[test]
    fn empty_ring_places_nothing() {
        let r = Ring::new(0, DEFAULT_VNODES);
        assert!(r.place("k", 1, |_| true).is_empty());
    }
}
