//! Backend connection pool with health tracking.
//!
//! One slot per configured backend, each holding a lazily established,
//! cached v2 [`Client`] connection plus an up/down mark and the latest
//! health-probe snapshot. All forwarding goes through [`Pool::with_client`],
//! which centralises the error taxonomy the router lives by:
//!
//! - a **wire rejection** (`Error::Wire`) means the daemon answered — the
//!   connection is intact and the error passes through untouched (and the
//!   node is confirmed alive);
//! - **anything else** (I/O failure, protocol garbage, EOF) means the
//!   connection state is unknown — tear it down, reconnect once and retry,
//!   and if that also fails mark the backend down and surface a retryable
//!   [`ErrorCode::Unavailable`] so callers can fail over.
//!
//! The per-slot connection mutex serialises requests to one backend; the
//! fan-out paths (stats, shutdown) iterate slots sequentially, which is
//! fine at fleet sizes this tier targets (single digits of nodes).

use std::time::Duration;

use crate::error::{Error, ErrorCode, Result};
use crate::serve::client::{Client, ProbeInfo};
use crate::util::sync::atomic::{AtomicBool, Ordering};
use crate::util::sync::Mutex;

struct Slot {
    addr: String,
    conn: Mutex<Option<Client>>,
    /// Optimistic until proven otherwise: a fresh pool treats every
    /// backend as up so first requests route normally; the first failed
    /// exchange or probe corrects the mark.
    up: AtomicBool,
    probe: Mutex<Option<ProbeInfo>>,
}

pub(crate) struct Pool {
    slots: Vec<Slot>,
    timeout: Duration,
}

impl Pool {
    pub(crate) fn new(addrs: &[String], timeout: Duration) -> Pool {
        Pool {
            slots: addrs
                .iter()
                .map(|a| Slot {
                    addr: a.clone(),
                    conn: Mutex::new(None),
                    up: AtomicBool::new(true),
                    probe: Mutex::new(None),
                })
                .collect(),
            timeout,
        }
    }

    pub(crate) fn len(&self) -> usize {
        self.slots.len()
    }

    pub(crate) fn addr(&self, slot: usize) -> &str {
        &self.slots[slot].addr
    }

    pub(crate) fn is_up(&self, slot: usize) -> bool {
        // Acquire/Release on the up flag per the signal-flag policy in
        // util/sync.rs. Routing reads it as a placement hint only; the
        // authoritative failure handling is with_client's error taxonomy.
        self.slots[slot].up.load(Ordering::Acquire)
    }

    /// Slots currently marked up, in index order.
    pub(crate) fn alive(&self) -> Vec<usize> {
        (0..self.slots.len()).filter(|&s| self.is_up(s)).collect()
    }

    /// Latest health-probe snapshot for a slot, if any probe succeeded.
    pub(crate) fn last_probe(&self, slot: usize) -> Option<ProbeInfo> {
        self.slots[slot].probe.lock().unwrap().clone()
    }

    /// Cached queue pressure for load-aware routing: queued + running
    /// from the last probe, zero when the node has never answered one.
    pub(crate) fn load(&self, slot: usize) -> usize {
        self.slots[slot]
            .probe
            .lock()
            .unwrap()
            .as_ref()
            .map(|p| p.queued + p.running)
            .unwrap_or(0)
    }

    /// Aggregate (queued, running) across up slots, from the probe cache
    /// — the router's own ping answer, with no fan-out on the ping path.
    pub(crate) fn fleet_load(&self) -> (usize, usize) {
        let mut queued = 0;
        let mut running = 0;
        for (i, s) in self.slots.iter().enumerate() {
            if !self.is_up(i) {
                continue;
            }
            if let Some(p) = s.probe.lock().unwrap().as_ref() {
                queued += p.queued;
                running += p.running;
            }
        }
        (queued, running)
    }

    fn connect(&self, addr: &str) -> Result<Client> {
        let mut c = Client::connect_with_timeout(addr, self.timeout)?;
        c.set_io_timeout(Some(self.timeout))?;
        c.negotiate()?;
        Ok(c)
    }

    /// Run `f` on the slot's cached connection, establishing one as
    /// needed. Transport failures tear the connection down, reconnect
    /// once and retry `f`; a second failure marks the backend down and
    /// reports `unavailable` (retryable — callers fail over to another
    /// candidate). Wire rejections pass through and confirm liveness.
    ///
    /// Note `f` may run twice; every verb forwarded through here is a
    /// single request/response exchange, so the only duplication hazard
    /// is a resend after a lost response — see the double-submit caveat
    /// in DESIGN.md.
    pub(crate) fn with_client<T>(
        &self,
        slot: usize,
        mut f: impl FnMut(&mut Client) -> Result<T>,
    ) -> Result<T> {
        let s = &self.slots[slot];
        let mut guard = s.conn.lock().unwrap();
        let mut last: Option<Error> = None;
        for _attempt in 0..2 {
            if guard.is_none() {
                match self.connect(&s.addr) {
                    Ok(c) => *guard = Some(c),
                    Err(e) => {
                        last = Some(e);
                        continue;
                    }
                }
            }
            match f(guard.as_mut().unwrap()) {
                Ok(v) => {
                    s.up.store(true, Ordering::Release);
                    return Ok(v);
                }
                Err(e @ Error::Wire { .. }) => {
                    s.up.store(true, Ordering::Release);
                    return Err(e);
                }
                Err(e) => {
                    *guard = None;
                    last = Some(e);
                }
            }
        }
        s.up.store(false, Ordering::Release);
        let detail = last.map(|e| e.to_string()).unwrap_or_else(|| "unreachable".into());
        Err(Error::wire(
            ErrorCode::Unavailable,
            format!("backend {}: {detail}", s.addr),
        ))
    }

    /// One health probe: refresh the slot's load snapshot via the v2
    /// enriched ping. Success marks the node up (stale snapshots are
    /// overwritten); transport failure marks it down via `with_client`.
    /// A pre-probe daemon that answers the ping with a bare ok counts as
    /// alive with no load snapshot. Returns the resulting up mark.
    pub(crate) fn probe_once(&self, slot: usize) -> bool {
        let r = self.with_client(slot, |c| match c.probe() {
            Ok(p) => Ok(Some(p)),
            Err(Error::Serve(msg)) if msg.contains("node identity") => Ok(None),
            Err(e) => Err(e),
        });
        if let Ok(snapshot) = r {
            *self.slots[slot].probe.lock().unwrap() = snapshot;
        }
        self.is_up(slot)
    }
}
