//! Fleet router: a daemon tier in front of N registration daemons.
//!
//! The router listens on the same NDJSON wire protocol the daemons speak
//! (v1 and v2), so an unmodified [`Client`](crate::serve::Client) — and
//! therefore every existing CLI subcommand — can point at a router
//! instead of a single daemon and transparently work against a fleet:
//!
//! - **Volume placement** (`upload`): the router hashes the payload to
//!   its content id and places it on [`RouterConfig::replication`] ring
//!   successors ([`placement::Ring`], consistent hashing with virtual
//!   nodes); `replication: 0` replicates fleet-wide (atlas volumes).
//! - **Affinity routing** (`submit`): uploaded-pair jobs go to a node
//!   that already holds *both* volumes — ranked by ring preference on
//!   the pair key so repeat pairs reuse warm operator caches — and fail
//!   over on backpressure (`queue_full`) or node loss with jittered
//!   backoff ([`RetryPolicy`]). Synthetic jobs go to the least-loaded
//!   live node (load from the health-probe cache).
//! - **Global job ids**: the router answers `submit` with its own id
//!   space and keeps a `global -> (backend, local)` routing table,
//!   journaled as NDJSON for restart (`status`/`cancel`/`watch` keep
//!   working across a router restart; in-flight `routed` counters are
//!   not journaled and restart at zero).
//! - **Federated control plane**: `stats` fans out and merges (with a
//!   per-node breakdown in `ServeStats::nodes`), `status` merges live
//!   backends, `watch` multiplexes every backend's event stream into
//!   one ordered, id-translated stream ([`federate::EventFan`]), and
//!   `shutdown` drains the whole fleet with one verb.
//! - **Health**: a prober thread sweeps the backends every
//!   [`RouterConfig::probe_interval`] via the enriched v2 ping; failed
//!   exchanges mark a node down (placement and routing skip it), the
//!   next successful probe marks it back up.
//!
//! What the router is *not*: it holds no volume bytes (placement is
//! forwarding, not caching), does not migrate data when a node dies
//! (re-upload re-places), and does not dedupe jobs — a transport failure
//! after a backend admitted a job can surface as an error to the client
//! even though the job runs (the double-submit caveat; see DESIGN.md).

mod federate;
mod forward;
pub mod placement;
mod pool;

pub use placement::Ring;

use std::collections::{BTreeMap, BTreeSet};
use std::io::{BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::time::Duration;

use crate::error::{Error, ErrorCode, Result};
use crate::serve::client::RetryPolicy;
use crate::serve::daemon::{wake_accept, write_line};
use crate::serve::proto::{
    read_request_line_bounded, EventMsg, Request, Response, Verdict, MAX_LINE_BYTES,
    MAX_UPLOAD_LINE_BYTES, PROTO_V2_FEATURES, PROTO_VERSION,
};
use crate::serve::scheduler::JobId;
use crate::util::json::Json;
use crate::util::sync::atomic::{AtomicBool, Ordering};
use crate::util::sync::thread::{self, JoinHandle};
use crate::util::sync::{Arc, Mutex};

use federate::{with_seq, EventFan, FanMsg, FanSub, FAN_QUEUE_CAP};
use placement::DEFAULT_VNODES;
use pool::Pool;

/// Router configuration; [`Default`] gives a loopback router with no
/// backends (which [`Router::start`] rejects — a fleet needs nodes).
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// Bind address of the router's own listener.
    pub addr: String,
    /// Backend daemon addresses. Slot order defines ring node indices,
    /// so keep it stable across restarts or journaled routes to a
    /// renamed backend are dropped on replay.
    pub backends: Vec<String>,
    /// Distinct holders per uploaded volume: `1` = single placement,
    /// `k` = the key's first k ring successors, `0` = every node.
    pub replication: usize,
    /// Health-probe sweep period.
    pub probe_interval: Duration,
    /// Per-backend I/O timeout (connect and each read/write).
    pub timeout: Duration,
    /// Routing-table journal path (`None` disables persistence).
    pub journal: Option<PathBuf>,
    /// Identity this router reports to v2 ping probes; generated from
    /// the bind address when absent.
    pub node_id: Option<String>,
    /// Backoff policy for submit failover and upload forwarding.
    pub retry: RetryPolicy,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            addr: "127.0.0.1:7470".into(),
            backends: Vec::new(),
            replication: 1,
            probe_interval: Duration::from_millis(500),
            timeout: Duration::from_secs(5),
            journal: None,
            node_id: None,
            retry: RetryPolicy::default(),
        }
    }
}

#[derive(Clone, Copy, Debug)]
struct RouteEntry {
    slot: usize,
    local: JobId,
}

struct VolumeEntry {
    n: usize,
    holders: BTreeSet<usize>,
}

/// Mutable routing state, all under one lock (every touch is a map
/// operation; contention is bounded by fleet request rate, not solves).
struct RouterState {
    next_global: JobId,
    routes: BTreeMap<JobId, RouteEntry>,
    reverse: BTreeMap<(usize, JobId), JobId>,
    volumes: BTreeMap<String, VolumeEntry>,
    /// Jobs routed per slot since this router started (not journaled).
    routed: Vec<u64>,
}

/// Append-only NDJSON journal of routing decisions. Replay is
/// torn-line-tolerant (a crash mid-write loses at most the final line)
/// and skips entries naming backends absent from the current config.
struct RouterJournal {
    file: Mutex<std::fs::File>,
}

impl RouterJournal {
    fn open(path: &Path) -> Result<RouterJournal> {
        let file = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
        Ok(RouterJournal { file: Mutex::new(file) })
    }

    fn append(&self, j: Json) {
        let mut f = self.file.lock().unwrap();
        let _ = writeln!(f, "{}", j.render());
        let _ = f.flush();
    }

    fn route_line(global: JobId, backend: &str, local: JobId) -> Json {
        Json::object([
            ("kind", Json::str("route")),
            ("global", Json::num(global as f64)),
            ("backend", Json::str(backend)),
            ("local", Json::num(local as f64)),
        ])
    }

    fn volume_line(id: &str, n: usize, backend: &str) -> Json {
        Json::object([
            ("kind", Json::str("volume")),
            ("id", Json::str(id)),
            ("n", Json::num(n as f64)),
            ("backend", Json::str(backend)),
        ])
    }
}

/// Rebuild routing state from a journal. Entries for backends no longer
/// in the config are skipped, but their global ids stay reserved so a
/// restarted router never re-issues an id a client may still hold.
fn replay_journal(path: &Path, backends: &[String], st: &mut RouterState) {
    let Ok(text) = std::fs::read_to_string(path) else {
        return; // missing file = fresh state
    };
    let slot_of = |addr: &str| backends.iter().position(|a| a == addr);
    for line in text.lines() {
        let Ok(j) = Json::parse(line.trim()) else {
            continue; // torn tail line from a crash mid-append
        };
        match j.get("kind").and_then(Json::as_str) {
            Some("route") => {
                let (Some(global), Some(addr), Some(local)) = (
                    j.get("global").and_then(Json::as_index),
                    j.get("backend").and_then(Json::as_str),
                    j.get("local").and_then(Json::as_index),
                ) else {
                    continue;
                };
                st.next_global = st.next_global.max(global + 1);
                if let Some(slot) = slot_of(addr) {
                    st.routes.insert(global, RouteEntry { slot, local });
                    st.reverse.insert((slot, local), global);
                }
            }
            Some("volume") => {
                let (Some(id), Some(n), Some(addr)) = (
                    j.get("id").and_then(Json::as_str),
                    j.get("n").and_then(Json::as_usize),
                    j.get("backend").and_then(Json::as_str),
                ) else {
                    continue;
                };
                if let Some(slot) = slot_of(addr) {
                    st.volumes
                        .entry(id.to_string())
                        .or_insert_with(|| VolumeEntry { n, holders: BTreeSet::new() })
                        .holders
                        .insert(slot);
                }
            }
            _ => {}
        }
    }
}

/// Shared router state: everything the connection handlers, prober and
/// backend watchers need.
pub(crate) struct Fleet {
    pub(crate) cfg: RouterConfig,
    pub(crate) pool: Pool,
    pub(crate) ring: Ring,
    pub(crate) st: Mutex<RouterState>,
    journal: Option<RouterJournal>,
    pub(crate) fan: EventFan,
    shutdown: AtomicBool,
    pub(crate) node_id: String,
    addr: SocketAddr,
}

impl Fleet {
    pub(crate) fn is_shutting_down(&self) -> bool {
        // Signal-flag policy (util/sync.rs): Acquire pairs with the
        // AcqRel swap in initiate_shutdown.
        self.shutdown.load(Ordering::Acquire)
    }

    pub(crate) fn lookup_global(&self, slot: usize, local: JobId) -> Option<JobId> {
        self.st.lock().unwrap().reverse.get(&(slot, local)).copied()
    }

    /// Resolve a global id to its backend route.
    pub(crate) fn route(&self, global: JobId) -> Result<(usize, JobId)> {
        self.st
            .lock()
            .unwrap()
            .routes
            .get(&global)
            .map(|r| (r.slot, r.local))
            .ok_or_else(|| Error::wire(ErrorCode::UnknownJob, format!("no such job {global}")))
    }

    /// Commit a placed job to the routing table and journal; returns the
    /// newly assigned global id.
    pub(crate) fn record_route(&self, slot: usize, local: JobId) -> JobId {
        let mut st = self.st.lock().unwrap();
        let global = st.next_global;
        st.next_global += 1;
        st.routes.insert(global, RouteEntry { slot, local });
        st.reverse.insert((slot, local), global);
        st.routed[slot] += 1;
        if let Some(j) = &self.journal {
            j.append(RouterJournal::route_line(global, self.pool.addr(slot), local));
        }
        global
    }

    /// Record (and journal) which backends acknowledged a volume.
    pub(crate) fn record_volume(&self, id: &str, n: usize, slots: &[usize]) {
        let mut st = self.st.lock().unwrap();
        let entry = st
            .volumes
            .entry(id.to_string())
            .or_insert_with(|| VolumeEntry { n, holders: BTreeSet::new() });
        for &slot in slots {
            if entry.holders.insert(slot) {
                if let Some(j) = &self.journal {
                    j.append(RouterJournal::volume_line(id, n, self.pool.addr(slot)));
                }
            }
        }
    }

    /// Decorrelate submit backoff jitter across concurrent submits.
    pub(crate) fn seed_mix(&self) -> u64 {
        self.st.lock().unwrap().next_global
    }

    /// Stop the router tier: flip the flag, wake the accept loop, end
    /// every watch stream. Does not touch the backends.
    fn initiate_shutdown(&self) {
        if !self.shutdown.swap(true, Ordering::AcqRel) {
            self.fan.close_all();
            wake_accept(self.addr);
        }
    }
}

fn generated_router_id(addr: &SocketAddr) -> String {
    let t = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    let label = format!("{addr}/{}/{t}", std::process::id());
    format!("router-{:016x}", placement::fnv64(label.as_bytes()))
}

pub struct Router;

impl Router {
    /// Bind the router, replay its journal, and spawn the health prober,
    /// one watch-federation thread per backend, and the accept loop.
    pub fn start(cfg: RouterConfig) -> Result<RouterHandle> {
        if cfg.backends.is_empty() {
            return Err(Error::Config("router needs at least one backend address".into()));
        }
        let listener = TcpListener::bind(&cfg.addr)?;
        let addr = listener.local_addr()?;
        let mut st = RouterState {
            next_global: 1,
            routes: BTreeMap::new(),
            reverse: BTreeMap::new(),
            volumes: BTreeMap::new(),
            routed: vec![0; cfg.backends.len()],
        };
        let journal = match &cfg.journal {
            Some(path) => {
                replay_journal(path, &cfg.backends, &mut st);
                Some(RouterJournal::open(path)?)
            }
            None => None,
        };
        let node_id = cfg.node_id.clone().unwrap_or_else(|| generated_router_id(&addr));
        let fleet = Arc::new(Fleet {
            pool: Pool::new(&cfg.backends, cfg.timeout),
            ring: Ring::new(cfg.backends.len(), DEFAULT_VNODES),
            st: Mutex::new(st),
            journal,
            fan: EventFan::new(FAN_QUEUE_CAP),
            shutdown: AtomicBool::new(false),
            node_id,
            addr,
            cfg,
        });
        let mut threads = Vec::new();
        {
            // Health prober: sweep every backend each interval. The first
            // sweep runs immediately so load-aware routing has data fast.
            let fleet = fleet.clone();
            threads.push(thread::spawn(move || {
                while !fleet.is_shutting_down() {
                    for slot in 0..fleet.pool.len() {
                        fleet.pool.probe_once(slot);
                    }
                    thread::sleep(fleet.cfg.probe_interval);
                }
            }));
        }
        threads.extend(federate::spawn_watchers(&fleet));
        {
            let accept_fleet = fleet.clone();
            threads.push(thread::spawn(move || {
                for conn in listener.incoming() {
                    if accept_fleet.is_shutting_down() {
                        break;
                    }
                    let Ok(stream) = conn else { continue };
                    let conn_fleet = accept_fleet.clone();
                    thread::spawn(move || handle_router_connection(stream, conn_fleet));
                }
            }));
        }
        Ok(RouterHandle { fleet, threads })
    }
}

/// Handle on a running router.
pub struct RouterHandle {
    fleet: Arc<Fleet>,
    threads: Vec<JoinHandle<()>>,
}

impl RouterHandle {
    /// The actually bound listener address (resolves `:0` binds).
    pub fn addr(&self) -> SocketAddr {
        self.fleet.addr
    }

    pub fn node_id(&self) -> &str {
        &self.fleet.node_id
    }

    /// Stop the router from the host process. `drain_backends` also fans
    /// a drain shutdown out to the whole fleet (the wire verb's
    /// semantics); `false` stops only the router tier, leaving backends
    /// running — what a rolling router upgrade wants.
    pub fn shutdown(&self, drain_backends: bool) {
        if drain_backends {
            forward::handle_shutdown(&self.fleet, true);
        }
        self.fleet.initiate_shutdown();
    }

    /// Wait for every router thread to exit (probe, watchers, accept).
    pub fn join(mut self) -> Result<()> {
        for t in self.threads.drain(..) {
            t.join().map_err(|_| Error::Serve("router thread panicked".into()))?;
        }
        Ok(())
    }
}

/// Forward fan messages to one watching connection until its stream ends
/// (lagged out, unsubscribed, router shutdown, or the peer stopped
/// accepting writes). Mirrors the daemon's `forward_events`.
fn forward_fan(sub: FanSub, writer: Arc<Mutex<TcpStream>>, fleet: Arc<Fleet>, seq: Option<u64>) {
    while let Some(msg) = sub.recv() {
        let line = match msg {
            FanMsg::Event(ev) => with_seq(ev, seq).to_line(),
            FanMsg::Lagged => EventMsg::Lagged { seq }.to_line(),
        };
        if !write_line(&writer, &line) {
            break;
        }
    }
    fleet.fan.unsubscribe(sub.id());
}

/// One client connection to the router. Mirrors the daemon's request
/// loop byte-for-byte on the session plumbing (negotiation, seq echo,
/// line caps, bad-request handling) and swaps the local scheduler/store
/// dispatch for fleet forwarding.
fn handle_router_connection(stream: TcpStream, fleet: Arc<Fleet>) {
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let writer = Arc::new(Mutex::new(stream));
    let mut v2 = false;
    let mut watch_sub: Option<u64> = None;
    let render = |resp: &Response, v2: bool, seq: Option<u64>| -> String {
        if v2 {
            resp.to_line_v2(seq)
        } else {
            resp.to_line()
        }
    };
    loop {
        let line = match read_request_line_bounded(
            &mut reader,
            MAX_LINE_BYTES,
            MAX_UPLOAD_LINE_BYTES,
        ) {
            Ok(Some(l)) => l,
            Ok(None) => break,
            Err(e) => {
                let resp = Response::Error {
                    code: ErrorCode::BadRequest,
                    retryable: false,
                    msg: format!("bad request line: {e}"),
                };
                let _ = write_line(&writer, &render(&resp, v2, None));
                break;
            }
        };
        if line.trim().is_empty() {
            continue;
        }
        let (raw_seq, parsed) = Request::parse_line(&line);
        let req = match parsed {
            Ok(r) => r,
            Err(e) => {
                let resp = Response::Error {
                    code: ErrorCode::BadRequest,
                    retryable: false,
                    msg: e.to_string(),
                };
                let seq = if v2 { raw_seq } else { None };
                if !write_line(&writer, &render(&resp, v2, seq)) {
                    break;
                }
                continue;
            }
        };
        let (response, shutdown) = match req {
            Request::Hello { proto } => {
                if proto >= 2 {
                    v2 = true;
                    (
                        Response::Hello {
                            proto: proto.min(PROTO_VERSION),
                            features: PROTO_V2_FEATURES.iter().map(|s| s.to_string()).collect(),
                        },
                        None,
                    )
                } else {
                    v2 = false;
                    if let Some(id) = watch_sub.take() {
                        fleet.fan.unsubscribe(id);
                    }
                    (Response::Hello { proto: 1, features: Vec::new() }, None)
                }
            }
            Request::Watch if !v2 => (
                Response::from_error(&Error::wire(
                    ErrorCode::BadRequest,
                    "unknown command 'watch'",
                )),
                None,
            ),
            Request::SubmitBatch(_) if !v2 => (
                Response::from_error(&Error::wire(
                    ErrorCode::BadRequest,
                    "unknown command 'submit_batch'",
                )),
                None,
            ),
            Request::Reduce(_) if !v2 => (
                Response::from_error(&Error::wire(
                    ErrorCode::BadRequest,
                    "unknown command 'reduce'",
                )),
                None,
            ),
            Request::Watch => {
                if watch_sub.is_some_and(|id| fleet.fan.is_subscribed(id)) {
                    (
                        Response::from_error(&Error::wire(
                            ErrorCode::InvalidState,
                            "this connection is already watching",
                        )),
                        None,
                    )
                } else {
                    let sub = fleet.fan.subscribe();
                    watch_sub = Some(sub.id());
                    let fw_writer = writer.clone();
                    let fw_fleet = fleet.clone();
                    thread::spawn(move || forward_fan(sub, fw_writer, fw_fleet, raw_seq));
                    (Response::Ok, None)
                }
            }
            Request::Ping if v2 => (forward::handle_probe(&fleet), None),
            Request::Ping => (Response::Ok, None),
            Request::Upload { n, data } => match forward::handle_upload(&fleet, n, data) {
                Ok(resp) => (resp, None),
                Err(e) => (Response::from_error(&e), None),
            },
            Request::Submit(spec) => match forward::handle_submit(&fleet, &spec) {
                Ok(id) => (Response::Submitted { id }, None),
                Err(e) => (Response::from_error(&e), None),
            },
            Request::SubmitBatch(specs) => {
                let verdicts = specs
                    .iter()
                    .map(|spec| Verdict::from_result(forward::handle_submit(&fleet, spec)))
                    .collect();
                (Response::Batch(verdicts), None)
            }
            Request::Status(None) => match forward::handle_jobs(&fleet) {
                Ok(views) => (Response::Jobs(views), None),
                Err(e) => (Response::from_error(&e), None),
            },
            Request::Status(Some(id)) => match forward::handle_status_one(&fleet, id) {
                Ok(view) => (Response::Job(view), None),
                Err(e) => (Response::from_error(&e), None),
            },
            Request::Cancel(id) => match forward::handle_cancel(&fleet, id) {
                Ok(()) => (Response::Ok, None),
                Err(e) => (Response::from_error(&e), None),
            },
            Request::Reduce(r) => match forward::handle_reduce(&fleet, r) {
                Ok(resp) => (resp, None),
                Err(e) => (Response::from_error(&e), None),
            },
            Request::Stats => (Response::Stats(forward::handle_stats(&fleet)), None),
            Request::Shutdown { drain } => (Response::Ok, Some(drain)),
        };
        let seq = if v2 { raw_seq } else { None };
        if !write_line(&writer, &render(&response, v2, seq)) {
            break;
        }
        if let Some(drain) = shutdown {
            // Acknowledge first (done above), then drain the fleet and
            // stop the router tier — one verb, whole-fleet semantics.
            forward::handle_shutdown(&fleet, drain);
            fleet.initiate_shutdown();
            break;
        }
    }
    if let Some(id) = watch_sub {
        fleet.fan.unsubscribe(id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn journal_replay_restores_routes_and_volumes() {
        let dir = std::env::temp_dir().join(format!("claire-router-journal-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("route_journal.ndjson");
        let backends = vec!["127.0.0.1:1".to_string(), "127.0.0.1:2".to_string()];
        {
            let j = RouterJournal::open(&path).unwrap();
            j.append(RouterJournal::route_line(1, "127.0.0.1:2", 7));
            j.append(RouterJournal::route_line(2, "127.0.0.1:9", 3)); // gone from config
            j.append(RouterJournal::volume_line("abc", 16, "127.0.0.1:1"));
        }
        // Torn tail line from a crash mid-append must not break replay.
        {
            use std::io::Write as _;
            let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
            write!(f, "{{\"kind\":\"rou").unwrap();
        }
        let mut st = RouterState {
            next_global: 1,
            routes: BTreeMap::new(),
            reverse: BTreeMap::new(),
            volumes: BTreeMap::new(),
            routed: vec![0; 2],
        };
        replay_journal(&path, &backends, &mut st);
        // Global ids continue past everything journaled, including the
        // dropped route for the removed backend.
        assert_eq!(st.next_global, 3);
        assert_eq!(st.routes.len(), 1);
        assert_eq!(st.routes[&1].slot, 1);
        assert_eq!(st.routes[&1].local, 7);
        assert_eq!(st.reverse[&(1, 7)], 1);
        assert_eq!(st.volumes["abc"].n, 16);
        assert!(st.volumes["abc"].holders.contains(&0));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn start_rejects_empty_fleet() {
        let cfg = RouterConfig { addr: "127.0.0.1:0".into(), ..RouterConfig::default() };
        assert!(Router::start(cfg).is_err());
    }
}
