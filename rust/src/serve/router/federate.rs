//! Federated watch plane: fan every backend's event stream into one
//! ordered stream per router subscriber.
//!
//! One watcher thread per backend keeps a dedicated `watch` connection
//! open, translates backend-local job ids into router-global ids via the
//! routing table, and publishes into the [`EventFan`]. The fan is the
//! router-side analogue of the scheduler's event bus: bounded per-
//! subscriber queues, a terminal `lagged` marker for slow consumers, and
//! publication under one registry lock so every subscriber observes the
//! same total event order (events from different backends have no
//! inherent order; the fan's arrival order is the order clients see).
//!
//! Jobs the router did not place carry local ids that mean nothing in
//! the global id space; their events are dropped rather than forwarded
//! with ambiguous ids. The one subtlety is a *race on routed jobs*: a
//! backend pushes the `queued` event during the submit round trip, so
//! the watcher can observe it before `record_route` commits the mapping.
//! `translate` therefore grants a missing id a short grace period of
//! lookup retries before concluding the job is foreign.

use std::collections::VecDeque;
use std::time::Duration;

use crate::error::Error;
use crate::serve::client::Client;
use crate::serve::proto::EventMsg;
use crate::serve::router::Fleet;
use crate::util::sync::thread::{self, JoinHandle};
use crate::util::sync::{Arc, Condvar, Mutex};

/// Bounded per-subscriber queue depth, matching the scheduler bus cap.
pub(crate) const FAN_QUEUE_CAP: usize = 256;

/// What a fan subscriber receives.
pub(crate) enum FanMsg {
    Event(EventMsg),
    /// Terminal: this subscriber fell behind (or a backend's own stream
    /// lagged, losing events upstream for everyone). The subscription is
    /// closed after delivery, mirroring the scheduler bus contract.
    Lagged,
}

struct SubQ {
    items: VecDeque<EventMsg>,
    lagged: bool,
    closed: bool,
}

struct SubShared {
    q: Mutex<SubQ>,
    cv: Condvar,
}

/// One subscription handle; dropping it without `unsubscribe` leaks the
/// registry entry until the fan is closed, so the connection handler
/// always unsubscribes on exit.
pub(crate) struct FanSub {
    id: u64,
    shared: Arc<SubShared>,
}

impl FanSub {
    pub(crate) fn id(&self) -> u64 {
        self.id
    }

    /// Blocking receive: the next message, or `None` once the
    /// subscription is closed (unsubscribed, fan shut down, or after a
    /// terminal `Lagged` was delivered).
    pub(crate) fn recv(&self) -> Option<FanMsg> {
        let mut q = self.shared.q.lock().unwrap();
        loop {
            if let Some(ev) = q.items.pop_front() {
                return Some(FanMsg::Event(ev));
            }
            if q.lagged {
                q.lagged = false;
                q.closed = true;
                return Some(FanMsg::Lagged);
            }
            if q.closed {
                return None;
            }
            q = self.shared.cv.wait(q).unwrap();
        }
    }
}

struct FanInner {
    next: u64,
    subs: Vec<(u64, Arc<SubShared>)>,
}

/// The fan-in bus: publish once, deliver to every live subscriber.
pub(crate) struct EventFan {
    inner: Mutex<FanInner>,
    cap: usize,
}

impl EventFan {
    pub(crate) fn new(cap: usize) -> EventFan {
        EventFan { inner: Mutex::new(FanInner { next: 1, subs: Vec::new() }), cap: cap.max(1) }
    }

    pub(crate) fn subscribe(&self) -> FanSub {
        let shared = Arc::new(SubShared {
            q: Mutex::new(SubQ { items: VecDeque::new(), lagged: false, closed: false }),
            cv: Condvar::new(),
        });
        let mut inner = self.inner.lock().unwrap();
        let id = inner.next;
        inner.next += 1;
        inner.subs.push((id, shared.clone()));
        FanSub { id, shared }
    }

    pub(crate) fn is_subscribed(&self, id: u64) -> bool {
        self.inner.lock().unwrap().subs.iter().any(|(i, _)| *i == id)
    }

    pub(crate) fn unsubscribe(&self, id: u64) {
        let mut inner = self.inner.lock().unwrap();
        if let Some(pos) = inner.subs.iter().position(|(i, _)| *i == id) {
            let (_, shared) = inner.subs.remove(pos);
            shared.q.lock().unwrap().closed = true;
            shared.cv.notify_all();
        }
    }

    /// Publish one (already id-translated) event to every subscriber.
    /// Runs under the registry lock so concurrent backend watchers
    /// interleave at event granularity — all subscribers see one total
    /// order. A subscriber at its bounded depth has its queue cleared
    /// and is marked lagged (terminal), never blocking the publishers.
    pub(crate) fn publish(&self, ev: &EventMsg) {
        let inner = self.inner.lock().unwrap();
        for (_, shared) in &inner.subs {
            let mut q = shared.q.lock().unwrap();
            if q.lagged || q.closed {
                continue;
            }
            if q.items.len() >= self.cap {
                q.items.clear();
                q.lagged = true;
            } else {
                q.items.push_back(ev.clone());
            }
            shared.cv.notify_all();
        }
    }

    /// A backend's own stream lagged: events were lost upstream, so every
    /// subscriber is lagged by definition — no queue depth can hide it.
    pub(crate) fn lag_all(&self) {
        let inner = self.inner.lock().unwrap();
        for (_, shared) in &inner.subs {
            let mut q = shared.q.lock().unwrap();
            if q.closed {
                continue;
            }
            q.items.clear();
            q.lagged = true;
            shared.cv.notify_all();
        }
    }

    /// Close every subscription (router shutdown): receivers drain what
    /// is queued and then see end-of-stream.
    pub(crate) fn close_all(&self) {
        let mut inner = self.inner.lock().unwrap();
        for (_, shared) in inner.subs.drain(..) {
            shared.q.lock().unwrap().closed = true;
            shared.cv.notify_all();
        }
    }
}

/// Replace an event's correlation seq (events are forwarded to each
/// subscriber with the seq *their* watch request carried).
pub(crate) fn with_seq(mut ev: EventMsg, seq: Option<u64>) -> EventMsg {
    match &mut ev {
        EventMsg::Job { seq: s, .. }
        | EventMsg::Progress { seq: s, .. }
        | EventMsg::Lagged { seq: s } => *s = seq,
    }
    ev
}

/// Spawn one watcher thread per backend slot.
pub(crate) fn spawn_watchers(fleet: &Arc<Fleet>) -> Vec<JoinHandle<()>> {
    (0..fleet.pool.len())
        .map(|slot| {
            let fleet = fleet.clone();
            thread::spawn(move || watcher_loop(&fleet, slot))
        })
        .collect()
}

fn watcher_loop(fleet: &Fleet, slot: usize) {
    let mut failures: u32 = 0;
    while !fleet.is_shutting_down() {
        match watch_once(fleet, slot) {
            Ok(()) => failures = 0,
            Err(_) => failures = failures.saturating_add(1),
        }
        if fleet.is_shutting_down() {
            break;
        }
        // Linear backoff on consecutive failures so an unreachable (or
        // v1-only) backend costs a connect attempt every few seconds,
        // not a tight reconnect spin.
        let ms = 200u64.saturating_mul(failures.max(1) as u64).min(5_000);
        thread::sleep(Duration::from_millis(ms));
    }
}

/// One watch session against a backend: connect, negotiate, subscribe,
/// then translate-and-publish events until the stream breaks or the
/// router shuts down. Short read timeouts keep the loop responsive to
/// the shutdown flag; on an idle local stream they fire at line
/// boundaries and are swallowed.
fn watch_once(fleet: &Fleet, slot: usize) -> crate::error::Result<()> {
    let addr = fleet.pool.addr(slot).to_string();
    let mut c = Client::connect_with_timeout(&addr, Duration::from_secs(3))?;
    if c.negotiate()? < 2 {
        return Err(Error::Serve(format!(
            "backend {addr} speaks protocol v1 only; watch federation needs v2"
        )));
    }
    c.watch()?;
    c.set_io_timeout(Some(Duration::from_millis(500)))?;
    loop {
        if fleet.is_shutting_down() {
            return Ok(());
        }
        match c.next_event() {
            Ok(EventMsg::Lagged { .. }) => {
                // The backend dropped this watcher: events were lost
                // upstream, so lag every fan subscriber, then reconnect
                // and resubscribe from live state.
                fleet.fan.lag_all();
                return Ok(());
            }
            Ok(ev) => {
                if let Some(gev) = translate(fleet, slot, ev) {
                    fleet.fan.publish(&gev);
                }
            }
            Err(Error::Io(e))
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                continue;
            }
            Err(e) => return Err(e),
        }
    }
}

/// Map a backend-local event into the router's global id space; `None`
/// drops it (foreign job, or the backend emitted a bare `lagged` which
/// `watch_once` already intercepts). A missing mapping gets a brief
/// grace period of retries to cover the submit/record race before the
/// event is declared foreign.
fn translate(fleet: &Fleet, slot: usize, ev: EventMsg) -> Option<EventMsg> {
    let local = match &ev {
        EventMsg::Job { id, .. } => *id,
        EventMsg::Progress { id, .. } => *id,
        EventMsg::Lagged { .. } => return None,
    };
    let mut global = fleet.lookup_global(slot, local);
    for _ in 0..10 {
        if global.is_some() {
            break;
        }
        thread::sleep(Duration::from_millis(5));
        global = fleet.lookup_global(slot, local);
    }
    let global = global?;
    Some(match ev {
        EventMsg::Job { seq: _, id: _, name, state, wall_s, error } => {
            EventMsg::Job { seq: None, id: global, name, state, wall_s, error }
        }
        EventMsg::Progress { seq: _, id: _, name, iter, level, beta, j, grad_rel, alpha } => {
            EventMsg::Progress { seq: None, id: global, name, iter, level, beta, j, grad_rel, alpha }
        }
        EventMsg::Lagged { .. } => unreachable!("intercepted above"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::scheduler::JobState;

    fn ev(id: u64) -> EventMsg {
        EventMsg::Job {
            seq: None,
            id,
            name: format!("job-{id}"),
            state: JobState::Queued,
            wall_s: None,
            error: None,
        }
    }

    #[test]
    fn fan_delivers_in_publish_order() {
        let fan = EventFan::new(16);
        let sub = fan.subscribe();
        for i in 1..=3 {
            fan.publish(&ev(i));
        }
        for i in 1..=3 {
            match sub.recv() {
                Some(FanMsg::Event(EventMsg::Job { id, .. })) => assert_eq!(id, i),
                _ => panic!("expected job event {i}"),
            }
        }
        fan.unsubscribe(sub.id());
        assert!(sub.recv().is_none());
    }

    #[test]
    fn slow_subscriber_lags_out_terminally() {
        let fan = EventFan::new(2);
        let sub = fan.subscribe();
        for i in 0..5 {
            fan.publish(&ev(i));
        }
        // Queue overflowed: pending items were dropped, one terminal
        // lagged marker is delivered, then end-of-stream.
        assert!(matches!(sub.recv(), Some(FanMsg::Lagged)));
        assert!(sub.recv().is_none());
        // The registry entry survives until unsubscribed.
        assert!(fan.is_subscribed(sub.id()));
        fan.unsubscribe(sub.id());
        assert!(!fan.is_subscribed(sub.id()));
    }

    #[test]
    fn lag_all_and_close_all() {
        let fan = EventFan::new(16);
        let a = fan.subscribe();
        let b = fan.subscribe();
        fan.publish(&ev(1));
        fan.lag_all();
        assert!(matches!(a.recv(), Some(FanMsg::Lagged)));
        assert!(matches!(b.recv(), Some(FanMsg::Lagged)));
        let c = fan.subscribe();
        fan.close_all();
        assert!(c.recv().is_none());
    }

    #[test]
    fn with_seq_rewrites_every_variant() {
        let j = with_seq(ev(7), Some(42));
        assert!(matches!(j, EventMsg::Job { seq: Some(42), .. }));
        let l = with_seq(EventMsg::Lagged { seq: None }, Some(1));
        assert!(matches!(l, EventMsg::Lagged { seq: Some(1) }));
    }
}
