//! Content-addressed volume store: the daemon-side half of the serve data
//! plane.
//!
//! The `upload` wire verb lands real volume data here; `submit` jobs with
//! an uploaded source resolve their `(m0, m1)` content ids against it at
//! admission time. Four properties carry the design:
//!
//! * **Content addressing** — a volume's id is a hash of its shape and
//!   bytes (FNV-1a 128), so re-uploading the same scan is a dedup hit,
//!   not a second copy. A population study registering one atlas against
//!   N subjects stores the atlas once. Vector fields (retained solve
//!   velocities, `reduce` outputs) live in the same map under a disjoint
//!   hash domain, so a scalar id can never resolve to a velocity.
//! * **Byte-budget LRU eviction** — the store holds at most `budget`
//!   bytes of volume data; least-recently-used volumes are evicted first.
//!   Jobs are immune to eviction once admitted: the scheduler payload
//!   carries `Arc<Field3>` resolved at submit time, so eviction only
//!   invalidates *future* submits referencing the id.
//! * **Pinning** — [`pin`](VolumeStore::pin)/[`unpin`](VolumeStore::unpin)
//!   refcounts exempt a volume from eviction entirely: the template
//!   driver pins the evolving template (and admission pins the volumes of
//!   queued jobs) so a cold-start byte budget cannot evict them
//!   mid-round. When every resident volume is pinned, a put admits *over*
//!   budget rather than failing — pins are correctness, the budget is a
//!   target.
//! * **Reject-on-shape-mismatch** — a put whose sample count is not n^3
//!   (or whose n is outside the wire bound) is an error, mirroring the
//!   protocol-level validation so in-process users (benches, tests,
//!   embedding) get the same contract as the wire.

use std::collections::BTreeMap;

use crate::error::{Error, ErrorCode, Result};
use crate::field::{Field3, VecField3};
use crate::serve::proto::MAX_GRID_N;
use crate::util::sync::{Arc, Mutex};

/// FNV-1a 128-bit (offset basis / prime per the FNV spec). Not
/// cryptographic — the store is a cache keyed by honest content, not a
/// defense against adversarial collisions — but 128 bits make accidental
/// collisions across a clinical workload negligible.
const FNV_OFFSET: u128 = 0x6c62272e07bb014262b821756295c58d;
const FNV_PRIME: u128 = 0x0000000001000000000000000000013b;

fn fnv1a(mut h: u128, bytes: &[u8]) -> u128 {
    for &b in bytes {
        h ^= b as u128;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

fn fnv1a_samples(mut h: u128, data: &[f32]) -> u128 {
    for &x in data {
        h = fnv1a(h, &x.to_le_bytes());
    }
    h
}

/// Content id of a scalar volume: hash of the grid size and the
/// little-endian sample bytes, rendered as 32 hex chars.
pub fn content_id(n: usize, data: &[f32]) -> String {
    let h = fnv1a(FNV_OFFSET, &(n as u64).to_le_bytes());
    format!("{:032x}", fnv1a_samples(h, data))
}

/// Content id of a vector (velocity) field: same construction under a
/// disjoint hash domain (a `"vec:"` prefix enters the hash), so vector
/// ids can never collide with scalar ids even for byte-identical data.
pub fn content_id_vec(n: usize, data: &[f32]) -> String {
    let h = fnv1a(FNV_OFFSET, b"vec:");
    let h = fnv1a(h, &(n as u64).to_le_bytes());
    format!("{:032x}", fnv1a_samples(h, data))
}

/// What a successful put returns (and the `upload` verb echoes).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct UploadReceipt {
    pub id: String,
    pub n: usize,
    pub bytes: u64,
    /// True when the volume was already resident (content-addressed hit).
    pub dedup: bool,
}

/// Aggregate store statistics (nested under `"store"` in the stats verb).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StoreStats {
    /// Volumes currently resident.
    pub volumes: usize,
    /// Bytes currently resident.
    pub bytes: u64,
    /// Total puts (dedup hits included).
    pub uploads: u64,
    /// Puts answered by an already-resident volume — observable proof the
    /// content addressing is doing its job.
    pub dedup_hits: u64,
    /// Volumes evicted by the byte budget.
    pub evictions: u64,
    /// Volumes currently pinned against eviction (templates, volumes of
    /// admitted-but-queued jobs). On the wire this travels only when
    /// non-zero, keeping a never-pinning daemon's stats byte-identical.
    pub pinned: usize,
}

/// What one entry holds: a scalar image volume or a vector velocity
/// field. The two kinds share the map (and the byte budget) but live in
/// disjoint content-id domains.
enum Stored {
    Scalar(Arc<Field3>),
    Vector(Arc<VecField3>),
}

impl Stored {
    fn n(&self) -> usize {
        match self {
            Stored::Scalar(f) => f.n,
            Stored::Vector(v) => v.n,
        }
    }

    fn is_vector(&self) -> bool {
        matches!(self, Stored::Vector(_))
    }
}

struct Entry {
    data: Stored,
    bytes: u64,
    /// Logical clock of the last put/get touch (LRU order).
    last_used: u64,
    /// Eviction-exemption refcount; 0 = ordinary LRU resident.
    pins: u32,
}

struct Inner {
    entries: BTreeMap<String, Entry>,
    clock: u64,
    bytes: u64,
    uploads: u64,
    dedup_hits: u64,
    evictions: u64,
}

/// Thread-safe content-addressed volume store with a byte budget.
pub struct VolumeStore {
    budget: u64,
    inner: Mutex<Inner>,
}

impl VolumeStore {
    /// A store holding at most `budget_bytes` of volume data (min: one
    /// 16^3 volume, so a misconfigured budget still admits the smallest
    /// artifact size).
    pub fn new(budget_bytes: u64) -> VolumeStore {
        VolumeStore {
            budget: budget_bytes.max(16 * 16 * 16 * 4),
            inner: Mutex::new(Inner {
                entries: BTreeMap::new(),
                clock: 0,
                bytes: 0,
                uploads: 0,
                dedup_hits: 0,
                evictions: 0,
            }),
        }
    }

    fn check_n(&self, n: usize) -> Result<()> {
        if n == 0 || n > MAX_GRID_N {
            return Err(Error::wire(
                ErrorCode::BadRequest,
                format!("volume n = {n} out of range (1..={MAX_GRID_N})"),
            ));
        }
        Ok(())
    }

    /// Admit a scalar volume. Same content twice is a dedup hit (same id,
    /// no second copy); a new volume may evict least-recently-used
    /// *unpinned* residents to fit the budget. Errors: shape mismatch, n
    /// out of range, or a single volume larger than the whole budget.
    pub fn put(&self, n: usize, data: Vec<f32>) -> Result<UploadReceipt> {
        self.check_n(n)?;
        if data.len() != n * n * n {
            return Err(Error::ShapeMismatch {
                what: format!("uploaded volume ({n}^3)"),
                expected: n * n * n,
                got: data.len(),
            });
        }
        let id = content_id(n, &data);
        self.put_entry(id, n, Stored::Scalar(Arc::new(Field3 { n, data })))
    }

    /// Admit a vector (velocity) field: 3*n^3 samples, same budget and
    /// eviction rules, content id in the vector hash domain.
    pub fn put_vec(&self, n: usize, data: Vec<f32>) -> Result<UploadReceipt> {
        self.check_n(n)?;
        if data.len() != 3 * n * n * n {
            return Err(Error::ShapeMismatch {
                what: format!("uploaded velocity field (3x{n}^3)"),
                expected: 3 * n * n * n,
                got: data.len(),
            });
        }
        let id = content_id_vec(n, &data);
        self.put_entry(id, n, Stored::Vector(Arc::new(VecField3 { n, data })))
    }

    fn put_entry(&self, id: String, n: usize, data: Stored) -> Result<UploadReceipt> {
        let bytes = match &data {
            Stored::Scalar(f) => (f.data.len() * 4) as u64,
            Stored::Vector(v) => (v.data.len() * 4) as u64,
        };
        if bytes > self.budget {
            return Err(Error::wire(
                ErrorCode::BadRequest,
                format!(
                    "volume of {bytes} bytes exceeds the store budget ({} bytes)",
                    self.budget
                ),
            ));
        }
        let mut st = self.inner.lock().unwrap();
        let st = &mut *st; // split-borrow the guard's fields
        st.clock += 1;
        st.uploads += 1;
        let clock = st.clock;
        if let Some(e) = st.entries.get_mut(&id) {
            // 128-bit collision between different volumes is negligible;
            // the shape check still guards the impossible-in-practice case
            // so a collision could never hand a job the wrong grid size —
            // or the wrong kind (scalar vs vector domains are disjoint by
            // construction, checked here anyway).
            if e.data.n() != n || e.data.is_vector() != data.is_vector() {
                return Err(Error::Serve(format!("content id collision on '{id}'")));
            }
            e.last_used = clock;
            st.dedup_hits += 1;
            return Ok(UploadReceipt { id, n, bytes, dedup: true });
        }
        while st.bytes + bytes > self.budget {
            // Pinned volumes are never victims. When everything resident
            // is pinned, admit over budget: the budget is a target, pins
            // are correctness (an evicted template kills a round).
            let Some(victim) = st
                .entries
                .iter()
                .filter(|(_, e)| e.pins == 0)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone())
            else {
                break;
            };
            let evicted = st.entries.remove(&victim).expect("victim came from the map");
            st.bytes -= evicted.bytes;
            st.evictions += 1;
        }
        st.bytes += bytes;
        st.entries.insert(id.clone(), Entry { data, bytes, last_used: clock, pins: 0 });
        Ok(UploadReceipt { id, n, bytes, dedup: false })
    }

    /// Resolve a scalar content id. A hit refreshes the volume's LRU
    /// position (jobs re-referencing a volume keep it warm). Vector ids
    /// resolve `None` here — use [`get_vec`](VolumeStore::get_vec).
    pub fn get(&self, id: &str) -> Option<Arc<Field3>> {
        let mut st = self.inner.lock().unwrap();
        st.clock += 1;
        let clock = st.clock;
        let e = st.entries.get_mut(id)?;
        let Stored::Scalar(f) = &e.data else { return None };
        let f = f.clone();
        e.last_used = clock;
        Some(f)
    }

    /// Resolve a vector (velocity) content id; scalar ids resolve `None`.
    pub fn get_vec(&self, id: &str) -> Option<Arc<VecField3>> {
        let mut st = self.inner.lock().unwrap();
        st.clock += 1;
        let clock = st.clock;
        let e = st.entries.get_mut(id)?;
        let Stored::Vector(v) = &e.data else { return None };
        let v = v.clone();
        e.last_used = clock;
        Some(v)
    }

    /// Exempt a resident volume from eviction (refcounted: pin twice,
    /// unpin twice). Returns false when the id is not resident — callers
    /// that need the volume later must treat that as a failed acquire.
    pub fn pin(&self, id: &str) -> bool {
        let mut st = self.inner.lock().unwrap();
        match st.entries.get_mut(id) {
            Some(e) => {
                e.pins += 1;
                true
            }
            None => false,
        }
    }

    /// Drop one pin (idempotent past zero, and a no-op for ids already
    /// evicted or never resident — unpin-after-evict must not panic).
    pub fn unpin(&self, id: &str) {
        let mut st = self.inner.lock().unwrap();
        if let Some(e) = st.entries.get_mut(id) {
            e.pins = e.pins.saturating_sub(1);
        }
    }

    pub fn stats(&self) -> StoreStats {
        let st = self.inner.lock().unwrap();
        StoreStats {
            volumes: st.entries.len(),
            bytes: st.bytes,
            uploads: st.uploads,
            dedup_hits: st.dedup_hits,
            evictions: st.evictions,
            pinned: st.entries.values().filter(|e| e.pins > 0).count(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vol(n: usize, seed: f32) -> Vec<f32> {
        (0..n * n * n).map(|i| seed + i as f32).collect()
    }

    fn vvol(n: usize, seed: f32) -> Vec<f32> {
        (0..3 * n * n * n).map(|i| seed + i as f32).collect()
    }

    #[test]
    fn content_id_is_deterministic_and_shape_sensitive() {
        let a = content_id(4, &vol(4, 0.0));
        assert_eq!(a, content_id(4, &vol(4, 0.0)));
        assert_ne!(a, content_id(4, &vol(4, 1.0)), "different data, different id");
        assert_eq!(a.len(), 32);
        assert!(a.bytes().all(|b| b.is_ascii_hexdigit()));
        // Vector ids live in a disjoint domain: identical bytes hash to a
        // different id, and both renderers agree on shape.
        let v = content_id_vec(4, &vol(4, 0.0));
        assert_ne!(a, v, "scalar and vector domains must not collide");
        assert_eq!(v, content_id_vec(4, &vol(4, 0.0)));
        assert_eq!(v.len(), 32);
    }

    #[test]
    fn dedup_hit_stores_one_copy() {
        let store = VolumeStore::new(1 << 20);
        let r1 = store.put(4, vol(4, 0.0)).unwrap();
        assert!(!r1.dedup);
        let r2 = store.put(4, vol(4, 0.0)).unwrap();
        assert!(r2.dedup);
        assert_eq!(r1.id, r2.id);
        let s = store.stats();
        assert_eq!(s.volumes, 1);
        assert_eq!(s.uploads, 2);
        assert_eq!(s.dedup_hits, 1);
        assert_eq!(s.bytes, (4 * 4 * 4 * 4) as u64);
        assert_eq!(store.get(&r1.id).unwrap().data, vol(4, 0.0));
    }

    #[test]
    fn shape_mismatch_and_bad_n_rejected() {
        let store = VolumeStore::new(1 << 20);
        assert!(store.put(4, vec![0.0; 63]).is_err(), "63 != 4^3");
        assert!(store.put(0, vec![]).is_err());
        assert!(store.put(MAX_GRID_N + 1, vec![0.0; 8]).is_err());
        assert!(store.put_vec(4, vec![0.0; 64]).is_err(), "64 != 3*4^3");
        assert!(store.put_vec(0, vec![]).is_err());
        assert_eq!(store.stats().volumes, 0);
    }

    #[test]
    fn vector_entries_resolve_only_through_get_vec() {
        let store = VolumeStore::new(1 << 20);
        let rv = store.put_vec(4, vvol(4, 0.0)).unwrap();
        assert!(!rv.dedup);
        assert_eq!(rv.bytes, (3 * 64 * 4) as u64);
        assert_eq!(store.get_vec(&rv.id).unwrap().data, vvol(4, 0.0));
        assert!(store.get(&rv.id).is_none(), "vector id must not resolve as scalar");
        let rs = store.put(4, vol(4, 0.0)).unwrap();
        assert!(store.get_vec(&rs.id).is_none(), "scalar id must not resolve as vector");
        // Re-putting the identical field is a dedup hit, same as scalars.
        assert!(store.put_vec(4, vvol(4, 0.0)).unwrap().dedup);
        assert_eq!(store.stats().volumes, 2);
    }

    #[test]
    fn lru_eviction_honors_byte_budget_and_recency() {
        // Budget fits exactly two 16^3 volumes (16384 bytes each — also
        // the constructor's floor, so the budget is taken as-is).
        const V: u64 = 16 * 16 * 16 * 4;
        let store = VolumeStore::new(2 * V);
        let a = store.put(16, vol(16, 0.0)).unwrap().id;
        let b = store.put(16, vol(16, 1.0)).unwrap().id;
        // Touch a so b becomes the LRU victim.
        assert!(store.get(&a).is_some());
        let c = store.put(16, vol(16, 2.0)).unwrap().id;
        assert!(store.get(&b).is_none(), "LRU volume evicted");
        assert!(store.get(&a).is_some(), "recently-used volume survives");
        assert!(store.get(&c).is_some());
        let s = store.stats();
        assert_eq!(s.volumes, 2);
        assert_eq!(s.evictions, 1);
        assert_eq!(s.bytes, 2 * V);
    }

    /// The template-eviction bug this PR fixes, reproduced: under a
    /// 2-volume budget, a round's subject uploads used to evict the
    /// (least-recently-touched) template out from under the driver.
    /// Pinning exempts it; unpinning restores ordinary LRU behavior.
    #[test]
    fn pinned_template_survives_lru_pressure() {
        const V: u64 = 16 * 16 * 16 * 4;
        let store = VolumeStore::new(2 * V);
        let template = store.put(16, vol(16, 0.0)).unwrap().id;
        assert!(store.pin(&template));
        // Two subject uploads: without the pin the template is the LRU
        // victim of the second (this exact sequence failed before).
        let s1 = store.put(16, vol(16, 1.0)).unwrap().id;
        let s2 = store.put(16, vol(16, 2.0)).unwrap().id;
        assert!(store.get(&template).is_some(), "pinned template survives");
        assert!(store.get(&s1).is_none(), "pressure fell on the unpinned subject");
        assert!(store.get(&s2).is_some());
        assert_eq!(store.stats().pinned, 1);
        // Unpin: the template rejoins the LRU pool. Touch the subject so
        // the template is the older resident, then overflow once more.
        store.unpin(&template);
        assert_eq!(store.stats().pinned, 0);
        assert!(store.get(&s2).is_some());
        // get(&template) above refreshed it; age it below s2 by touching
        // s2 last, then push a third volume.
        let s3 = store.put(16, vol(16, 3.0)).unwrap().id;
        assert!(store.get(&s3).is_some());
        assert!(store.get(&template).is_none(), "unpinned template evictable again");
        assert_eq!(store.stats().volumes, 2);
    }

    #[test]
    fn all_pinned_store_admits_over_budget() {
        // Budget of one volume, and that volume is pinned: the next put
        // must admit over budget (evicting the pinned resident would
        // corrupt a round; failing the put would wedge the driver).
        const V: u64 = 16 * 16 * 16 * 4;
        let store = VolumeStore::new(V);
        let a = store.put(16, vol(16, 0.0)).unwrap().id;
        assert!(store.pin(&a));
        let b = store.put(16, vol(16, 1.0)).unwrap().id;
        assert!(store.get(&a).is_some());
        assert!(store.get(&b).is_some());
        let s = store.stats();
        assert_eq!(s.volumes, 2);
        assert_eq!(s.evictions, 0);
        assert!(s.bytes > V, "over-budget admission is visible in stats");
        // Pins are refcounted; unpin of unknown ids is a quiet no-op.
        assert!(store.pin(&a));
        store.unpin(&a);
        assert_eq!(store.stats().pinned, 1, "one pin still held");
        store.unpin(&a);
        assert_eq!(store.stats().pinned, 0);
        store.unpin("never-resident");
        assert!(!store.pin("never-resident"));
    }

    #[test]
    fn volume_larger_than_budget_is_rejected_not_thrashed() {
        // Budget below one 16^3 volume is clamped up to exactly one, so a
        // 32^3 put must be rejected outright.
        let store = VolumeStore::new(1);
        assert!(store.put(16, vol(16, 0.0)).is_ok());
        let err = store.put(32, vol(32, 0.0)).unwrap_err();
        assert!(err.to_string().contains("budget"), "{err}");
        assert_eq!(store.stats().volumes, 1, "resident volume untouched");
    }

    #[test]
    fn eviction_is_invisible_to_resolved_handles() {
        // Budget of exactly one 16^3 volume: the second put evicts the
        // first.
        let store = VolumeStore::new(16 * 16 * 16 * 4);
        let a = store.put(16, vol(16, 0.0)).unwrap().id;
        let held = store.get(&a).unwrap();
        store.put(16, vol(16, 1.0)).unwrap(); // evicts a
        assert!(store.get(&a).is_none());
        // The Arc handed out at "admission" still owns the data.
        assert_eq!(held.data, vol(16, 0.0));
    }
}
