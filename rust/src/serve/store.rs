//! Content-addressed volume store: the daemon-side half of the serve data
//! plane.
//!
//! The `upload` wire verb lands real volume data here; `submit` jobs with
//! an uploaded source resolve their `(m0, m1)` content ids against it at
//! admission time. Three properties carry the design:
//!
//! * **Content addressing** — a volume's id is a hash of its shape and
//!   bytes (FNV-1a 128), so re-uploading the same scan is a dedup hit,
//!   not a second copy. A population study registering one atlas against
//!   N subjects stores the atlas once.
//! * **Byte-budget LRU eviction** — the store holds at most `budget`
//!   bytes of volume data; least-recently-used volumes are evicted first.
//!   Jobs are immune to eviction once admitted: the scheduler payload
//!   carries `Arc<Field3>` resolved at submit time, so eviction only
//!   invalidates *future* submits referencing the id.
//! * **Reject-on-shape-mismatch** — a put whose sample count is not n^3
//!   (or whose n is outside the wire bound) is an error, mirroring the
//!   protocol-level validation so in-process users (benches, tests,
//!   embedding) get the same contract as the wire.

use std::collections::BTreeMap;

use crate::error::{Error, ErrorCode, Result};
use crate::field::Field3;
use crate::serve::proto::MAX_GRID_N;
use crate::util::sync::{Arc, Mutex};

/// FNV-1a 128-bit (offset basis / prime per the FNV spec). Not
/// cryptographic — the store is a cache keyed by honest content, not a
/// defense against adversarial collisions — but 128 bits make accidental
/// collisions across a clinical workload negligible.
const FNV_OFFSET: u128 = 0x6c62272e07bb014262b821756295c58d;
const FNV_PRIME: u128 = 0x0000000001000000000000000000013b;

fn fnv1a(mut h: u128, bytes: &[u8]) -> u128 {
    for &b in bytes {
        h ^= b as u128;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Content id of a volume: hash of the grid size and the little-endian
/// sample bytes, rendered as 32 hex chars.
pub fn content_id(n: usize, data: &[f32]) -> String {
    let mut h = fnv1a(FNV_OFFSET, &(n as u64).to_le_bytes());
    for &x in data {
        h = fnv1a(h, &x.to_le_bytes());
    }
    format!("{h:032x}")
}

/// What a successful put returns (and the `upload` verb echoes).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct UploadReceipt {
    pub id: String,
    pub n: usize,
    pub bytes: u64,
    /// True when the volume was already resident (content-addressed hit).
    pub dedup: bool,
}

/// Aggregate store statistics (nested under `"store"` in the stats verb).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StoreStats {
    /// Volumes currently resident.
    pub volumes: usize,
    /// Bytes currently resident.
    pub bytes: u64,
    /// Total puts (dedup hits included).
    pub uploads: u64,
    /// Puts answered by an already-resident volume — observable proof the
    /// content addressing is doing its job.
    pub dedup_hits: u64,
    /// Volumes evicted by the byte budget.
    pub evictions: u64,
}

struct Entry {
    field: Arc<Field3>,
    bytes: u64,
    /// Logical clock of the last put/get touch (LRU order).
    last_used: u64,
}

struct Inner {
    entries: BTreeMap<String, Entry>,
    clock: u64,
    bytes: u64,
    uploads: u64,
    dedup_hits: u64,
    evictions: u64,
}

/// Thread-safe content-addressed volume store with a byte budget.
pub struct VolumeStore {
    budget: u64,
    inner: Mutex<Inner>,
}

impl VolumeStore {
    /// A store holding at most `budget_bytes` of volume data (min: one
    /// 16^3 volume, so a misconfigured budget still admits the smallest
    /// artifact size).
    pub fn new(budget_bytes: u64) -> VolumeStore {
        VolumeStore {
            budget: budget_bytes.max(16 * 16 * 16 * 4),
            inner: Mutex::new(Inner {
                entries: BTreeMap::new(),
                clock: 0,
                bytes: 0,
                uploads: 0,
                dedup_hits: 0,
                evictions: 0,
            }),
        }
    }

    /// Admit a volume. Same content twice is a dedup hit (same id, no
    /// second copy); a new volume may evict least-recently-used residents
    /// to fit the budget. Errors: shape mismatch, n out of range, or a
    /// single volume larger than the whole budget.
    pub fn put(&self, n: usize, data: Vec<f32>) -> Result<UploadReceipt> {
        if n == 0 || n > MAX_GRID_N {
            return Err(Error::wire(
                ErrorCode::BadRequest,
                format!("volume n = {n} out of range (1..={MAX_GRID_N})"),
            ));
        }
        if data.len() != n * n * n {
            return Err(Error::ShapeMismatch {
                what: format!("uploaded volume ({n}^3)"),
                expected: n * n * n,
                got: data.len(),
            });
        }
        let bytes = (data.len() * 4) as u64;
        if bytes > self.budget {
            return Err(Error::wire(
                ErrorCode::BadRequest,
                format!(
                    "volume of {bytes} bytes exceeds the store budget ({} bytes)",
                    self.budget
                ),
            ));
        }
        let id = content_id(n, &data);
        let mut st = self.inner.lock().unwrap();
        let st = &mut *st; // split-borrow the guard's fields
        st.clock += 1;
        st.uploads += 1;
        let clock = st.clock;
        if let Some(e) = st.entries.get_mut(&id) {
            // 128-bit collision between different volumes is negligible;
            // the shape check still guards the impossible-in-practice case
            // so a collision could never hand a job the wrong grid size.
            if e.field.n != n {
                return Err(Error::Serve(format!("content id collision on '{id}'")));
            }
            e.last_used = clock;
            st.dedup_hits += 1;
            return Ok(UploadReceipt { id, n, bytes, dedup: true });
        }
        while st.bytes + bytes > self.budget {
            let Some(victim) = st
                .entries
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone())
            else {
                break;
            };
            let evicted = st.entries.remove(&victim).expect("victim came from the map");
            st.bytes -= evicted.bytes;
            st.evictions += 1;
        }
        st.bytes += bytes;
        st.entries.insert(
            id.clone(),
            Entry { field: Arc::new(Field3 { n, data }), bytes, last_used: clock },
        );
        Ok(UploadReceipt { id, n, bytes, dedup: false })
    }

    /// Resolve a content id. A hit refreshes the volume's LRU position
    /// (jobs re-referencing a volume keep it warm).
    pub fn get(&self, id: &str) -> Option<Arc<Field3>> {
        let mut st = self.inner.lock().unwrap();
        st.clock += 1;
        let clock = st.clock;
        let e = st.entries.get_mut(id)?;
        e.last_used = clock;
        Some(e.field.clone())
    }

    pub fn stats(&self) -> StoreStats {
        let st = self.inner.lock().unwrap();
        StoreStats {
            volumes: st.entries.len(),
            bytes: st.bytes,
            uploads: st.uploads,
            dedup_hits: st.dedup_hits,
            evictions: st.evictions,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vol(n: usize, seed: f32) -> Vec<f32> {
        (0..n * n * n).map(|i| seed + i as f32).collect()
    }

    #[test]
    fn content_id_is_deterministic_and_shape_sensitive() {
        let a = content_id(4, &vol(4, 0.0));
        assert_eq!(a, content_id(4, &vol(4, 0.0)));
        assert_ne!(a, content_id(4, &vol(4, 1.0)), "different data, different id");
        assert_eq!(a.len(), 32);
        assert!(a.bytes().all(|b| b.is_ascii_hexdigit()));
    }

    #[test]
    fn dedup_hit_stores_one_copy() {
        let store = VolumeStore::new(1 << 20);
        let r1 = store.put(4, vol(4, 0.0)).unwrap();
        assert!(!r1.dedup);
        let r2 = store.put(4, vol(4, 0.0)).unwrap();
        assert!(r2.dedup);
        assert_eq!(r1.id, r2.id);
        let s = store.stats();
        assert_eq!(s.volumes, 1);
        assert_eq!(s.uploads, 2);
        assert_eq!(s.dedup_hits, 1);
        assert_eq!(s.bytes, (4 * 4 * 4 * 4) as u64);
        assert_eq!(store.get(&r1.id).unwrap().data, vol(4, 0.0));
    }

    #[test]
    fn shape_mismatch_and_bad_n_rejected() {
        let store = VolumeStore::new(1 << 20);
        assert!(store.put(4, vec![0.0; 63]).is_err(), "63 != 4^3");
        assert!(store.put(0, vec![]).is_err());
        assert!(store.put(MAX_GRID_N + 1, vec![0.0; 8]).is_err());
        assert_eq!(store.stats().volumes, 0);
    }

    #[test]
    fn lru_eviction_honors_byte_budget_and_recency() {
        // Budget fits exactly two 16^3 volumes (16384 bytes each — also
        // the constructor's floor, so the budget is taken as-is).
        const V: u64 = 16 * 16 * 16 * 4;
        let store = VolumeStore::new(2 * V);
        let a = store.put(16, vol(16, 0.0)).unwrap().id;
        let b = store.put(16, vol(16, 1.0)).unwrap().id;
        // Touch a so b becomes the LRU victim.
        assert!(store.get(&a).is_some());
        let c = store.put(16, vol(16, 2.0)).unwrap().id;
        assert!(store.get(&b).is_none(), "LRU volume evicted");
        assert!(store.get(&a).is_some(), "recently-used volume survives");
        assert!(store.get(&c).is_some());
        let s = store.stats();
        assert_eq!(s.volumes, 2);
        assert_eq!(s.evictions, 1);
        assert_eq!(s.bytes, 2 * V);
    }

    #[test]
    fn volume_larger_than_budget_is_rejected_not_thrashed() {
        // Budget below one 16^3 volume is clamped up to exactly one, so a
        // 32^3 put must be rejected outright.
        let store = VolumeStore::new(1);
        assert!(store.put(16, vol(16, 0.0)).is_ok());
        let err = store.put(32, vol(32, 0.0)).unwrap_err();
        assert!(err.to_string().contains("budget"), "{err}");
        assert_eq!(store.stats().volumes, 1, "resident volume untouched");
    }

    #[test]
    fn eviction_is_invisible_to_resolved_handles() {
        // Budget of exactly one 16^3 volume: the second put evicts the
        // first.
        let store = VolumeStore::new(16 * 16 * 16 * 4);
        let a = store.put(16, vol(16, 0.0)).unwrap().id;
        let held = store.get(&a).unwrap();
        store.put(16, vol(16, 1.0)).unwrap(); // evicts a
        assert!(store.get(&a).is_none());
        // The Arc handed out at "admission" still owns the data.
        assert_eq!(held.data, vol(16, 0.0));
    }
}
