//! Small statistics helpers used by metrics and benches.

/// Min / mean / max summary of a slice (paper Table 7 reports these for
/// the determinant of the deformation gradient).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Summary {
    pub min: f64,
    pub mean: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f32]) -> Summary {
        assert!(!xs.is_empty());
        let mut min = f64::INFINITY;
        let mut max = f64::NEG_INFINITY;
        let mut sum = 0.0f64;
        for &x in xs {
            let x = x as f64;
            min = min.min(x);
            max = max.max(x);
            sum += x;
        }
        Summary { min, mean: sum / xs.len() as f64, max }
    }
}

/// Percentile (nearest-rank) of a pre-sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    let rank = ((p / 100.0) * (sorted.len() as f64 - 1.0)).round() as usize;
    sorted[rank.min(sorted.len() - 1)]
}

/// Relative L2 difference ||a-b|| / ||b||.
pub fn rel_l2(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (&x, &y) in a.iter().zip(b) {
        num += ((x - y) as f64).powi(2);
        den += (y as f64).powi(2);
    }
    (num / den.max(1e-300)).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert!((s.mean - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_nearest_rank() {
        let v = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile_sorted(&v, 0.0), 1.0);
        assert_eq!(percentile_sorted(&v, 50.0), 3.0);
        assert_eq!(percentile_sorted(&v, 100.0), 5.0);
    }

    #[test]
    fn rel_l2_zero_for_equal() {
        let a = [1.0f32, -2.0, 3.0];
        assert_eq!(rel_l2(&a, &a), 0.0);
    }

    #[test]
    fn rel_l2_scales() {
        let a = [2.0f32, 0.0];
        let b = [1.0f32, 0.0];
        assert!((rel_l2(&a, &b) - 1.0).abs() < 1e-12);
    }
}
