//! Numerical substrates: FFT oracle, pure-Rust kernel references, stats.

pub mod fft;
pub mod kernels_ref;
pub mod stats;
