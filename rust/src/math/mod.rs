//! Numerical substrates: FFT oracle, pure-Rust kernel references, stats,
//! and f16/bf16 bit conversions for the mixed-precision policy.

pub mod fft;
pub mod half;
pub mod kernels_ref;
pub mod stats;
