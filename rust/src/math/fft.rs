//! Radix-2 complex FFT substrate (power-of-two sizes).
//!
//! The paper's CPU baseline uses FFTW/AccFFT and the GPU version cuFFT; at
//! runtime our spectral operators run inside XLA artifacts. This module is
//! the crate-internal *oracle*: it cross-validates the spectral artifacts'
//! numerics from the Rust side (tests), powers the Table-2 style intensity
//! accounting, and provides spectral utilities for synthetic-data checks.
//!
//! Iterative Cooley-Tukey with bit-reversal permutation; f64 throughout so
//! the oracle has headroom over the f32 artifacts it validates.

use std::f64::consts::PI;

/// Complex number (f64).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct C64 {
    pub re: f64,
    pub im: f64,
}

impl C64 {
    pub fn new(re: f64, im: f64) -> Self {
        C64 { re, im }
    }

    pub fn mul(self, o: C64) -> C64 {
        C64::new(self.re * o.re - self.im * o.im, self.re * o.im + self.im * o.re)
    }

    pub fn add(self, o: C64) -> C64 {
        C64::new(self.re + o.re, self.im + o.im)
    }

    pub fn sub(self, o: C64) -> C64 {
        C64::new(self.re - o.re, self.im - o.im)
    }

    pub fn scale(self, s: f64) -> C64 {
        C64::new(self.re * s, self.im * s)
    }

    pub fn abs(self) -> f64 {
        self.re.hypot(self.im)
    }
}

/// In-place forward FFT (no normalization). `data.len()` must be a power of 2.
pub fn fft(data: &mut [C64]) {
    transform(data, -1.0);
}

/// In-place inverse FFT (normalized by 1/N).
pub fn ifft(data: &mut [C64]) {
    transform(data, 1.0);
    let inv = 1.0 / data.len() as f64;
    for x in data.iter_mut() {
        *x = x.scale(inv);
    }
}

fn transform(data: &mut [C64], sign: f64) {
    let n = data.len();
    assert!(n.is_power_of_two(), "FFT size must be a power of two, got {n}");
    // Bit-reversal permutation.
    let bits = n.trailing_zeros();
    for i in 0..n {
        let j = (i.reverse_bits() >> (usize::BITS - bits)) as usize;
        if j > i {
            data.swap(i, j);
        }
    }
    // Butterflies.
    let mut len = 2;
    while len <= n {
        let ang = sign * 2.0 * PI / len as f64;
        let wlen = C64::new(ang.cos(), ang.sin());
        let mut i = 0;
        while i < n {
            let mut w = C64::new(1.0, 0.0);
            for j in 0..len / 2 {
                let u = data[i + j];
                let v = data[i + j + len / 2].mul(w);
                data[i + j] = u.add(v);
                data[i + j + len / 2] = u.sub(v);
                w = w.mul(wlen);
            }
            i += len;
        }
        len <<= 1;
    }
}

/// Forward 3-D FFT over a cubic grid stored row-major `[n, n, n]`.
pub fn fft3(data: &mut [C64], n: usize) {
    transform3(data, n, false);
}

/// Inverse 3-D FFT (normalized).
pub fn ifft3(data: &mut [C64], n: usize) {
    transform3(data, n, true);
}

fn transform3(data: &mut [C64], n: usize, inverse: bool) {
    assert_eq!(data.len(), n * n * n);
    let mut line = vec![C64::default(); n];
    let run = |line: &mut Vec<C64>| {
        if inverse {
            ifft(line);
        } else {
            fft(line);
        }
    };
    // Axis 2 (contiguous).
    for row in data.chunks_mut(n) {
        line.copy_from_slice(row);
        run(&mut line);
        row.copy_from_slice(&line);
    }
    // Axis 1 (stride n).
    for i in 0..n {
        for k in 0..n {
            for j in 0..n {
                line[j] = data[(i * n + j) * n + k];
            }
            run(&mut line);
            for j in 0..n {
                data[(i * n + j) * n + k] = line[j];
            }
        }
    }
    // Axis 0 (stride n*n).
    for j in 0..n {
        for k in 0..n {
            for i in 0..n {
                line[i] = data[(i * n + j) * n + k];
            }
            run(&mut line);
            for i in 0..n {
                data[(i * n + j) * n + k] = line[i];
            }
        }
    }
}

/// Integer wavenumber for index `i` on an n-point periodic grid.
pub fn wavenumber(i: usize, n: usize) -> f64 {
    if i <= n / 2 {
        i as f64
    } else {
        i as f64 - n as f64
    }
}

/// Spectral first derivative of a real f32 field along `axis` (oracle).
pub fn spectral_partial(f: &[f32], n: usize, axis: usize) -> Vec<f32> {
    let mut buf: Vec<C64> = f.iter().map(|&x| C64::new(x as f64, 0.0)).collect();
    fft3(&mut buf, n);
    for i in 0..n {
        for j in 0..n {
            for k in 0..n {
                let idx = [i, j, k][axis];
                let mut kk = wavenumber(idx, n);
                if n % 2 == 0 && idx == n / 2 {
                    kk = 0.0; // Nyquist of odd derivative
                }
                let v = buf[(i * n + j) * n + k];
                buf[(i * n + j) * n + k] = C64::new(-kk * v.im, kk * v.re);
            }
        }
    }
    ifft3(&mut buf, n);
    buf.iter().map(|c| c.re as f32).collect()
}

/// Naive DFT for validating the FFT (O(n^2); test sizes only).
pub fn dft_naive(data: &[C64]) -> Vec<C64> {
    let n = data.len();
    let mut out = vec![C64::default(); n];
    for (k, o) in out.iter_mut().enumerate() {
        let mut acc = C64::default();
        for (t, &x) in data.iter().enumerate() {
            let ang = -2.0 * PI * (k * t) as f64 / n as f64;
            acc = acc.add(x.mul(C64::new(ang.cos(), ang.sin())));
        }
        *o = acc;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn rand_signal(r: &mut Rng, n: usize) -> Vec<C64> {
        (0..n).map(|_| C64::new(r.normal(), r.normal())).collect()
    }

    #[test]
    fn fft_matches_naive_dft() {
        prop::check_msg(
            prop::Config { cases: 24, seed: 10 },
            |r| {
                let n = prop::pow2(r, 2, 64);
                rand_signal(r, n)
            },
            |sig| {
                let want = dft_naive(sig);
                let mut got = sig.clone();
                fft(&mut got);
                for (a, b) in got.iter().zip(&want) {
                    if a.sub(*b).abs() > 1e-9 * (1.0 + b.abs()) {
                        return Err(format!("mismatch {a:?} vs {b:?}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn fft_roundtrip() {
        prop::check_msg(
            prop::Config { cases: 24, seed: 11 },
            |r| {
                let n = prop::pow2(r, 2, 256);
                rand_signal(r, n)
            },
            |sig| {
                let mut got = sig.clone();
                fft(&mut got);
                ifft(&mut got);
                for (a, b) in got.iter().zip(sig) {
                    if a.sub(*b).abs() > 1e-10 {
                        return Err(format!("roundtrip {a:?} vs {b:?}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn parseval_energy_conserved() {
        let mut r = Rng::new(12);
        let sig = rand_signal(&mut r, 128);
        let e_time: f64 = sig.iter().map(|c| c.abs() * c.abs()).sum();
        let mut freq = sig.clone();
        fft(&mut freq);
        let e_freq: f64 = freq.iter().map(|c| c.abs() * c.abs()).sum::<f64>() / 128.0;
        assert!((e_time - e_freq).abs() < 1e-8 * e_time);
    }

    #[test]
    fn fft3_roundtrip() {
        let mut r = Rng::new(13);
        let n = 8;
        let sig = rand_signal(&mut r, n * n * n);
        let mut got = sig.clone();
        fft3(&mut got, n);
        ifft3(&mut got, n);
        for (a, b) in got.iter().zip(&sig) {
            assert!(a.sub(*b).abs() < 1e-10);
        }
    }

    #[test]
    fn fft3_plane_wave_is_delta() {
        // f(x) = exp(i k.x) transforms to a single spike at k.
        let n = 8;
        let kvec = [2usize, 5, 1];
        let mut data = vec![C64::default(); n * n * n];
        for i in 0..n {
            for j in 0..n {
                for k in 0..n {
                    let ph = 2.0 * PI * (kvec[0] * i + kvec[1] * j + kvec[2] * k) as f64 / n as f64;
                    data[(i * n + j) * n + k] = C64::new(ph.cos(), ph.sin());
                }
            }
        }
        fft3(&mut data, n);
        let spike = (kvec[0] * n + kvec[1]) * n + kvec[2];
        for (idx, c) in data.iter().enumerate() {
            if idx == spike {
                assert!((c.re - (n * n * n) as f64).abs() < 1e-6);
            } else {
                assert!(c.abs() < 1e-6, "leak at {idx}: {c:?}");
            }
        }
    }

    #[test]
    fn spectral_partial_of_sin_is_cos() {
        let n = 16;
        let mut f = vec![0f32; n * n * n];
        for i in 0..n {
            for j in 0..n {
                for k in 0..n {
                    let x3 = 2.0 * PI * k as f64 / n as f64;
                    f[(i * n + j) * n + k] = (3.0 * x3).sin() as f32;
                }
            }
        }
        let df = spectral_partial(&f, n, 2);
        for i in 0..n {
            for j in 0..n {
                for k in 0..n {
                    let x3 = 2.0 * PI * k as f64 / n as f64;
                    let want = 3.0 * (3.0 * x3).cos();
                    let got = df[(i * n + j) * n + k] as f64;
                    assert!((got - want).abs() < 1e-4, "at {k}: {got} vs {want}");
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn non_pow2_rejected() {
        let mut d = vec![C64::default(); 6];
        fft(&mut d);
    }
}
