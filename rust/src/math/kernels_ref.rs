//! Pure-Rust reference implementations of the paper's two hot kernels.
//!
//! These mirror `python/compile/kernels/ref.py` and serve three purposes:
//! 1. cross-language validation of the HLO artifacts (integration tests
//!    compare artifact outputs against these implementations);
//! 2. host-side fallbacks for utilities that do not warrant a PJRT call
//!    (e.g. nearest-neighbor label warping for DICE);
//! 3. the Fig-2 style accuracy study can run without artifacts.
//!
//! The `*_f16` variants emulate the mixed-precision kernels: every stored
//! value round-trips through IEEE binary16 bits (`math/half.rs`) while the
//! accumulator stays wide — the same fp16-storage / f32-accumulate split
//! the `*__mixed` artifacts use, so mixed artifacts can be cross-validated
//! on any host, no GPU (and no PJRT) required.

use std::f64::consts::PI;

use crate::math::half::f16_round;

/// Centered 8th-order first-derivative coefficients (offsets 1..4).
pub const FD8_COEFFS: [f64; 4] = [4.0 / 5.0, -1.0 / 5.0, 4.0 / 105.0, -1.0 / 280.0];

#[inline]
fn wrap(i: isize, n: usize) -> usize {
    i.rem_euclid(n as isize) as usize
}

/// FD8 partial derivative of scalar field `f[n,n,n]` along `axis`.
pub fn fd8_partial(f: &[f32], n: usize, axis: usize, h: f64) -> Vec<f32> {
    assert_eq!(f.len(), n * n * n);
    let stride = [n * n, n, 1][axis];
    let mut out = vec![0f32; f.len()];
    let at = |i: usize, j: usize, k: usize| (i * n + j) * n + k;
    for i in 0..n {
        for j in 0..n {
            for k in 0..n {
                let ijk = [i, j, k];
                let base = at(i, j, k) as isize;
                let pos = ijk[axis] as isize;
                let mut acc = 0.0f64;
                for (o, c) in FD8_COEFFS.iter().enumerate() {
                    let off = (o + 1) as isize;
                    let plus = base + (wrap(pos + off, n) as isize - pos) * stride as isize;
                    let minus = base + (wrap(pos - off, n) as isize - pos) * stride as isize;
                    acc += c * (f[plus as usize] as f64 - f[minus as usize] as f64);
                }
                out[at(i, j, k)] = (acc / h) as f32;
            }
        }
    }
    out
}

/// FD8 divergence of a vector field stored as 3 contiguous scalar fields.
pub fn fd8_div(v: &[f32], n: usize, h: f64) -> Vec<f32> {
    let m = n * n * n;
    assert_eq!(v.len(), 3 * m);
    let mut out = fd8_partial(&v[0..m], n, 0, h);
    for (axis, chunk) in [(1usize, &v[m..2 * m]), (2usize, &v[2 * m..3 * m])] {
        let d = fd8_partial(chunk, n, axis, h);
        for (o, x) in out.iter_mut().zip(d) {
            *o += x;
        }
    }
    out
}

/// Round a whole field through f16 storage (mixed-cache emulation: this
/// is what marshalling a tensor as an f16 literal does to its values).
pub fn round_field_f16(f: &[f32]) -> Vec<f32> {
    f.iter().map(|&x| f16_round(x)).collect()
}

/// FD8 partial derivative with fp16-emulated storage, mirroring the mixed
/// kernels' arithmetic exactly: stored values round through f16, each tap
/// *pair difference* is computed at f16 (the kernels subtract at storage
/// precision — `fd8._fd8_axis` widens only after the subtract), and the
/// coefficient FMA accumulates wide.
pub fn fd8_partial_f16(f: &[f32], n: usize, axis: usize, h: f64) -> Vec<f32> {
    assert_eq!(f.len(), n * n * n);
    let fs = round_field_f16(f);
    let stride = [n * n, n, 1][axis];
    let mut out = vec![0f32; f.len()];
    let at = |i: usize, j: usize, k: usize| (i * n + j) * n + k;
    for i in 0..n {
        for j in 0..n {
            for k in 0..n {
                let ijk = [i, j, k];
                let base = at(i, j, k) as isize;
                let pos = ijk[axis] as isize;
                let mut acc = 0.0f32;
                for (o, c) in FD8_COEFFS.iter().enumerate() {
                    let off = (o + 1) as isize;
                    let plus = base + (wrap(pos + off, n) as isize - pos) * stride as isize;
                    let minus = base + (wrap(pos - off, n) as isize - pos) * stride as isize;
                    let diff = f16_round(fs[plus as usize] - fs[minus as usize]);
                    acc += *c as f32 * diff;
                }
                out[at(i, j, k)] = acc / h as f32;
            }
        }
    }
    out
}

/// Trilinear periodic interpolation at one query point with fp16-emulated
/// storage: corner values and weights round through f16, products
/// accumulate in f32 — mirroring the reduced `interp_lin_f16` kernel.
pub fn interp_linear_at_f16(f: &[f32], n: usize, q: [f64; 3]) -> f64 {
    let i0: Vec<isize> = q.iter().map(|&x| x.floor() as isize).collect();
    let t: Vec<f32> = q
        .iter()
        .zip(&i0)
        .map(|(&x, &i)| f16_round((x - i as f64) as f32))
        .collect();
    let mut acc = 0.0f32;
    for dx in 0..2 {
        let wx = if dx == 1 { t[0] } else { f16_round(1.0 - t[0]) };
        for dy in 0..2 {
            let wy = if dy == 1 { t[1] } else { f16_round(1.0 - t[1]) };
            for dz in 0..2 {
                let wz = if dz == 1 { t[2] } else { f16_round(1.0 - t[2]) };
                let idx = (wrap(i0[0] + dx, n) * n + wrap(i0[1] + dy, n)) * n
                    + wrap(i0[2] + dz, n);
                let w = f16_round(f16_round(wx * wy) * wz);
                acc += w * f16_round(f[idx]);
            }
        }
    }
    acc as f64
}

/// Trilinear periodic interpolation at one query point (grid units).
pub fn interp_linear_at(f: &[f32], n: usize, q: [f64; 3]) -> f64 {
    let i0: Vec<isize> = q.iter().map(|&x| x.floor() as isize).collect();
    let t: Vec<f64> = q.iter().zip(&i0).map(|(&x, &i)| x - i as f64).collect();
    let mut acc = 0.0f64;
    for dx in 0..2 {
        let wx = if dx == 1 { t[0] } else { 1.0 - t[0] };
        for dy in 0..2 {
            let wy = if dy == 1 { t[1] } else { 1.0 - t[1] };
            for dz in 0..2 {
                let wz = if dz == 1 { t[2] } else { 1.0 - t[2] };
                let idx = (wrap(i0[0] + dx, n) * n + wrap(i0[1] + dy, n)) * n
                    + wrap(i0[2] + dz, n);
                acc += wx * wy * wz * f[idx] as f64;
            }
        }
    }
    acc
}

/// Cubic Lagrange basis at offsets (-1, 0, 1, 2) evaluated at t in [0,1).
pub fn lagrange_weights(t: f64) -> [f64; 4] {
    [
        -t * (t - 1.0) * (t - 2.0) / 6.0,
        (t + 1.0) * (t - 1.0) * (t - 2.0) / 2.0,
        -(t + 1.0) * t * (t - 2.0) / 2.0,
        (t + 1.0) * t * (t - 1.0) / 6.0,
    ]
}

/// Cubic Lagrange periodic interpolation at one query point (grid units).
pub fn interp_cubic_at(f: &[f32], n: usize, q: [f64; 3]) -> f64 {
    let i0: Vec<isize> = q.iter().map(|&x| x.floor() as isize).collect();
    let w: Vec<[f64; 4]> =
        q.iter().zip(&i0).map(|(&x, &i)| lagrange_weights(x - i as f64)).collect();
    let mut acc = 0.0f64;
    for dx in 0..4 {
        for dy in 0..4 {
            for dz in 0..4 {
                let idx = (wrap(i0[0] + dx - 1, n) * n + wrap(i0[1] + dy - 1, n)) * n
                    + wrap(i0[2] + dz - 1, n);
                acc += w[0][dx as usize] * w[1][dy as usize] * w[2][dz as usize] * f[idx] as f64;
            }
        }
    }
    acc
}

/// Nearest-neighbor periodic lookup (label warping for DICE).
pub fn sample_nearest(labels: &[u16], n: usize, q: [f64; 3]) -> u16 {
    let i = wrap(q[0].round() as isize, n);
    let j = wrap(q[1].round() as isize, n);
    let k = wrap(q[2].round() as isize, n);
    labels[(i * n + j) * n + k]
}

/// Evaluate `sin(w x3) + cos(w x3)` on the grid (the paper's Fig-2 probe).
pub fn fig2_probe(n: usize, omega: f64) -> Vec<f32> {
    let mut f = vec![0f32; n * n * n];
    for i in 0..n {
        for j in 0..n {
            for k in 0..n {
                let x3 = 2.0 * PI * k as f64 / n as f64;
                f[(i * n + j) * n + k] = ((omega * x3).sin() + (omega * x3).cos()) as f32;
            }
        }
    }
    f
}

/// Analytic x3-derivative of the Fig-2 probe.
pub fn fig2_probe_deriv(n: usize, omega: f64) -> Vec<f32> {
    let mut f = vec![0f32; n * n * n];
    for i in 0..n {
        for j in 0..n {
            for k in 0..n {
                let x3 = 2.0 * PI * k as f64 / n as f64;
                f[(i * n + j) * n + k] = (omega * ((omega * x3).cos() - (omega * x3).sin())) as f32;
            }
        }
    }
    f
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn fd8_exact_on_low_frequency() {
        // FD8 differentiates low-frequency trig almost exactly.
        let n = 16;
        let h = 2.0 * PI / n as f64;
        let f = fig2_probe(n, 2.0);
        let want = fig2_probe_deriv(n, 2.0);
        let got = fd8_partial(&f, n, 2, h);
        for (a, b) in got.iter().zip(&want) {
            // 8th-order truncation at (omega*h) ~ 0.79 leaves ~4e-4.
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn fd8_error_grows_with_frequency() {
        let n = 32;
        let h = 2.0 * PI / n as f64;
        let err = |omega: f64| {
            let f = fig2_probe(n, omega);
            let want = fig2_probe_deriv(n, omega);
            let got = fd8_partial(&f, n, 2, h);
            crate::math::stats::rel_l2(&got, &want)
        };
        // Paper Fig 2: FD error increases toward the Nyquist frequency.
        assert!(err(2.0) < err(8.0) && err(8.0) < err(14.0));
    }

    #[test]
    fn fd8_constant_field_zero_derivative() {
        let n = 8;
        let f = vec![3.5f32; n * n * n];
        let d = fd8_partial(&f, n, 1, 0.1);
        assert!(d.iter().all(|&x| x.abs() < 1e-5));
    }

    #[test]
    fn div_of_rotation_is_zero() {
        // v = (-x2, x1, 0) as periodic trig analog: v = (-sin x2, sin x1, 0)
        // has zero divergence.
        let n = 16;
        let h = 2.0 * PI / n as f64;
        let m = n * n * n;
        let mut v = vec![0f32; 3 * m];
        for i in 0..n {
            for j in 0..n {
                for k in 0..n {
                    let x1 = 2.0 * PI * i as f64 / n as f64;
                    let x2 = 2.0 * PI * j as f64 / n as f64;
                    v[(i * n + j) * n + k] = -(x2.sin()) as f32;
                    v[m + (i * n + j) * n + k] = x1.sin() as f32;
                }
            }
        }
        let d = fd8_div(&v, n, h);
        assert!(d.iter().all(|&x| x.abs() < 1e-5));
    }

    #[test]
    fn trilinear_exact_at_nodes_and_affine() {
        prop::check_msg(
            prop::Config { cases: 32, seed: 20 },
            |r| {
                let n = 8usize;
                let q = [
                    r.uniform_in(-8.0, 16.0),
                    r.uniform_in(-8.0, 16.0),
                    r.uniform_in(-8.0, 16.0),
                ];
                (n, q)
            },
            |&(n, q)| {
                // Constant field: interpolation is exact everywhere.
                let f = vec![2.5f32; n * n * n];
                let v = interp_linear_at(&f, n, q);
                if (v - 2.5).abs() > 1e-6 {
                    return Err(format!("constant broken: {v}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn cubic_partition_of_unity() {
        let mut r = Rng::new(21);
        for _ in 0..64 {
            let t = r.uniform();
            let w = lagrange_weights(t);
            let s: f64 = w.iter().sum();
            assert!((s - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn cubic_reproduces_cubics_1d() {
        // Cubic Lagrange reproduces polynomials of degree <= 3 away from
        // wrap effects: test on f(k) = k^3 within the interior.
        let n = 16;
        let mut f = vec![0f32; n * n * n];
        for i in 0..n {
            for j in 0..n {
                for k in 0..n {
                    f[(i * n + j) * n + k] = (k * k * k) as f32;
                }
            }
        }
        for &t in &[4.25, 7.5, 9.75] {
            let v = interp_cubic_at(&f, n, [5.0, 5.0, t]);
            assert!((v - t * t * t).abs() < 1e-3, "{v} vs {}", t * t * t);
        }
    }

    #[test]
    fn interp_at_grid_points_is_identity() {
        let mut r = Rng::new(22);
        let n = 8;
        let f: Vec<f32> = (0..n * n * n).map(|_| r.uniform_f32(-1.0, 1.0)).collect();
        for _ in 0..32 {
            let i = r.below(n as u64) as usize;
            let j = r.below(n as u64) as usize;
            let k = r.below(n as u64) as usize;
            let q = [i as f64, j as f64, k as f64];
            let want = f[(i * n + j) * n + k] as f64;
            assert!((interp_linear_at(&f, n, q) - want).abs() < 1e-6);
            assert!((interp_cubic_at(&f, n, q) - want).abs() < 1e-5);
        }
    }

    #[test]
    fn f16_reference_kernels_track_f32_within_storage_error() {
        // The fp16-emulating path must agree with the f32 reference to
        // within the f16 storage error amplified by the stencil: FD8 sums
        // |c_k| ~ 1.09 over value pairs of O(1), divided by h.
        let n = 16;
        let h = 2.0 * PI / n as f64;
        let f = fig2_probe(n, 2.0);
        let full = fd8_partial(&f, n, 2, h);
        let half = fd8_partial_f16(&f, n, 2, h);
        let rel = crate::math::stats::rel_l2(&half, &full);
        assert!(rel > 0.0, "f16 emulation must actually perturb the result");
        assert!(rel < 5e-3, "f16 FD8 drifted: rel {rel}");

        let mut r = Rng::new(23);
        let fr: Vec<f32> = (0..n * n * n).map(|_| r.uniform_f32(-1.0, 1.0)).collect();
        let mut max_err = 0.0f64;
        for _ in 0..256 {
            let q = [
                r.uniform_in(-8.0, 24.0),
                r.uniform_in(-8.0, 24.0),
                r.uniform_in(-8.0, 24.0),
            ];
            let a = interp_linear_at(&fr, n, q);
            let b = interp_linear_at_f16(&fr, n, q);
            max_err = max_err.max((a - b).abs());
        }
        // 8 corners of O(1) values, each stored at f16 (eps = 2^-11), plus
        // weight rounding: a few f16 ulps total.
        assert!(max_err < 5e-3, "f16 interp max err {max_err}");
    }

    #[test]
    fn f16_field_roundtrip_is_idempotent() {
        let mut r = Rng::new(24);
        let f: Vec<f32> = (0..64).map(|_| r.uniform_f32(-100.0, 100.0)).collect();
        let once = round_field_f16(&f);
        let twice = round_field_f16(&once);
        assert_eq!(once, twice, "f16 storage rounding must be idempotent");
        assert!(once.iter().zip(&f).any(|(a, b)| a != b));
    }

    #[test]
    fn nearest_sample_wraps() {
        let n = 4;
        let mut labels = vec![0u16; n * n * n];
        labels[0] = 7;
        assert_eq!(sample_nearest(&labels, n, [4.0, 0.1, -0.2]), 7);
    }
}
