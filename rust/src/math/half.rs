//! Pure-Rust IEEE-754 binary16 (f16) and bfloat16 bit conversions.
//!
//! The offline image has no `half` crate; these conversions are the host
//! side of the mixed-precision policy: `runtime/operator.rs` marshals
//! f32 host buffers into f16/bf16 XLA literals through them, and
//! `math/kernels_ref.rs` uses the round-trips to emulate fp16-storage
//! kernels in pure Rust (cross-validation of mixed artifacts without a
//! GPU). Rounding is round-to-nearest-even, matching XLA's `ConvertOp`.

/// Convert an f32 to IEEE binary16 bits (round-to-nearest-even; overflow
/// saturates to infinity, tiny values flush through the subnormal range).
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let mant = bits & 0x007f_ffff;
    if exp == 0xff {
        // Inf stays inf; NaN keeps a quiet payload bit.
        return sign | 0x7c00 | if mant != 0 { 0x0200 } else { 0 };
    }
    let e = exp - 127 + 15; // rebias
    if e >= 0x1f {
        return sign | 0x7c00; // overflow -> inf
    }
    if e <= 0 {
        // Subnormal range: shift the full significand (implicit bit set)
        // into the 10-bit subnormal field. Below 2^-24 everything rounds
        // to zero (shift > 24 leaves no half-ulp to round up on).
        if e < -10 {
            return sign;
        }
        let m = mant | 0x0080_0000;
        return sign | round_shift(m, (14 - e) as u32) as u16;
    }
    // Normal range: drop 13 mantissa bits with RNE. A mantissa carry-out
    // (0x400) propagates into the exponent field — including e == 30
    // rounding up to infinity — because the fields are adjacent.
    sign | (((e as u32) << 10) + round_shift(mant, 13)) as u16
}

/// Expand IEEE binary16 bits to f32 (exact; every f16 value is an f32).
pub fn f16_bits_to_f32(b: u16) -> f32 {
    let sign = ((b as u32) & 0x8000) << 16;
    let exp = ((b >> 10) & 0x1f) as u32;
    let mant = (b & 0x3ff) as u32;
    let bits = if exp == 0x1f {
        sign | 0x7f80_0000 | (mant << 13)
    } else if exp == 0 {
        if mant == 0 {
            sign // signed zero
        } else {
            // Normalize the subnormal: value = mant * 2^-24.
            let mut e = 113u32; // pre-shift exponent field (see loop)
            let mut m = mant;
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            sign | (e << 23) | ((m & 0x3ff) << 13)
        }
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

/// Convert an f32 to bfloat16 bits (round-to-nearest-even).
pub fn f32_to_bf16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return ((bits >> 16) as u16) | 0x0040; // quiet NaN, keep sign
    }
    let round = ((bits >> 16) & 1) + 0x7fff;
    ((bits + round) >> 16) as u16
}

/// Expand bfloat16 bits to f32 (exact).
pub fn bf16_bits_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// Round an f32 through f16 storage (the fp16-emulation primitive).
pub fn f16_round(x: f32) -> f32 {
    f16_bits_to_f32(f32_to_f16_bits(x))
}

/// Round an f32 through bf16 storage.
pub fn bf16_round(x: f32) -> f32 {
    bf16_bits_to_f32(f32_to_bf16_bits(x))
}

/// Marshal a whole f32 slice to f16 bits (literal building).
pub fn f16_bits_of(xs: &[f32]) -> Vec<u16> {
    xs.iter().map(|&x| f32_to_f16_bits(x)).collect()
}

/// Marshal a whole f32 slice to bf16 bits (literal building).
pub fn bf16_bits_of(xs: &[f32]) -> Vec<u16> {
    xs.iter().map(|&x| f32_to_bf16_bits(x)).collect()
}

/// Drop a 32-bit significand by `shift` bits, rounding to nearest even.
fn round_shift(m: u32, shift: u32) -> u32 {
    let v = m >> shift;
    let rem = m & ((1u32 << shift) - 1);
    let half = 1u32 << (shift - 1);
    if rem > half || (rem == half && (v & 1) == 1) {
        v + 1
    } else {
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn known_f16_encodings() {
        assert_eq!(f32_to_f16_bits(0.0), 0x0000);
        assert_eq!(f32_to_f16_bits(-0.0), 0x8000);
        assert_eq!(f32_to_f16_bits(1.0), 0x3c00);
        assert_eq!(f32_to_f16_bits(-2.0), 0xc000);
        assert_eq!(f32_to_f16_bits(0.5), 0x3800);
        assert_eq!(f32_to_f16_bits(65504.0), 0x7bff); // f16::MAX
        assert_eq!(f32_to_f16_bits(65536.0), 0x7c00); // overflow -> inf
        assert_eq!(f32_to_f16_bits(f32::INFINITY), 0x7c00);
        assert_eq!(f32_to_f16_bits(f32::NEG_INFINITY), 0xfc00);
        // Smallest subnormal 2^-24 and smallest normal 2^-14.
        assert_eq!(f32_to_f16_bits(2.0f32.powi(-24)), 0x0001);
        assert_eq!(f32_to_f16_bits(2.0f32.powi(-14)), 0x0400);
        // Below half the smallest subnormal: flush to zero.
        assert_eq!(f32_to_f16_bits(2.0f32.powi(-26)), 0x0000);
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
    }

    #[test]
    fn round_to_nearest_even() {
        // 1 + 2^-11 is exactly halfway between 1.0 and 1 + 2^-10: ties to
        // the even mantissa (1.0). 1 + 3*2^-12 is past halfway: rounds up.
        assert_eq!(f32_to_f16_bits(1.0 + 2.0f32.powi(-11)), 0x3c00);
        assert_eq!(f32_to_f16_bits(1.0 + 3.0 * 2.0f32.powi(-12)), 0x3c01);
        // Halfway above an odd mantissa rounds up to the even one.
        let odd = f16_bits_to_f32(0x3c01); // 1 + 2^-10
        assert_eq!(f32_to_f16_bits(odd + 2.0f32.powi(-11)), 0x3c02);
    }

    #[test]
    fn f16_roundtrip_is_idempotent_and_accurate() {
        let mut rng = Rng::new(7);
        for _ in 0..4096 {
            let x = rng.uniform_f32(-1e4, 1e4);
            let r = f16_round(x);
            // Idempotent: a stored value is exactly representable.
            assert_eq!(f32_to_f16_bits(r), f32_to_f16_bits(x));
            // Relative error bounded by the f16 half-ulp (2^-11).
            if x.abs() > 1e-3 {
                assert!(
                    ((r - x) / x).abs() <= 2.0f32.powi(-11),
                    "{x} -> {r}"
                );
            }
        }
    }

    #[test]
    fn all_f16_bit_patterns_roundtrip_exactly() {
        // f32 -> f16 must be the identity on values that came from f16.
        for b in 0u16..=0xffff {
            let x = f16_bits_to_f32(b);
            if x.is_nan() {
                assert!(f16_bits_to_f32(f32_to_f16_bits(x)).is_nan());
            } else {
                assert_eq!(f32_to_f16_bits(x), b, "bits {b:#06x} ({x})");
            }
        }
    }

    #[test]
    fn bf16_known_and_roundtrip() {
        assert_eq!(f32_to_bf16_bits(1.0), 0x3f80);
        assert_eq!(f32_to_bf16_bits(-1.5), 0xbfc0);
        assert_eq!(bf16_bits_to_f32(0x3f80), 1.0);
        assert!(bf16_bits_to_f32(f32_to_bf16_bits(f32::NAN)).is_nan());
        let mut rng = Rng::new(8);
        for _ in 0..4096 {
            let x = rng.uniform_f32(-1e6, 1e6);
            let r = bf16_round(x);
            assert_eq!(f32_to_bf16_bits(r), f32_to_bf16_bits(x));
            if x.abs() > 1e-3 {
                assert!(((r - x) / x).abs() <= 2.0f32.powi(-8), "{x} -> {r}");
            }
        }
    }
}
