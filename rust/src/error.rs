//! Error types for the CLAIRE coordinator, including the wire-protocol
//! error taxonomy.
//!
//! The serve wire protocol (v2) reports failures with a *stable machine
//! code* plus a `retryable` flag so clients — scripts driving the CLI,
//! batch drivers, fleet schedulers — can branch without parsing English.
//! [`ErrorCode`] is that registry; [`Error::Wire`] carries it through the
//! Rust layers, and every other `Error` variant maps onto a code via
//! [`Error::code`] so daemon responses are always classified.

use thiserror::Error;

/// Stable wire-protocol error codes (protocol v2's `"code"` field).
///
/// The string forms are a compatibility surface: once shipped, a code's
/// spelling never changes (clients branch on it). Add new codes instead of
/// repurposing old ones. See DESIGN.md's error-code registry for the
/// meaning, retryability, and CLI exit code of each.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorCode {
    /// Malformed request: unparseable line, unknown verb, mistyped or
    /// out-of-range field. Resending the same bytes can never succeed.
    BadRequest,
    /// Admission control refused the job: the bounded queue is full.
    /// Retryable — back off and resubmit.
    QueueFull,
    /// The daemon is shutting down and not admitting work. Retryable
    /// against a restarted daemon.
    ShuttingDown,
    /// `status`/`cancel` named a job id the daemon does not know.
    UnknownJob,
    /// A submit referenced a volume content id that was never uploaded or
    /// has been evicted; re-upload and resubmit.
    UnknownVolume,
    /// Payload geometry disagrees with its declaration (upload byte count
    /// vs `n`, job `n` vs stored volume shape).
    ShapeMismatch,
    /// The request is well-formed but the target is in the wrong state
    /// (e.g. cancelling a running or finished job).
    InvalidState,
    /// Transport-level failure: daemon unreachable, connection closed,
    /// I/O timeout. Mostly client-side classification, but the fleet
    /// router *does* send it on the wire when every candidate backend for
    /// a request is unreachable — still retryable, same exit code.
    Unavailable,
    /// Anything the daemon could not classify (executor failures, bugs).
    Internal,
}

impl ErrorCode {
    pub fn as_str(&self) -> &'static str {
        match self {
            ErrorCode::BadRequest => "bad_request",
            ErrorCode::QueueFull => "queue_full",
            ErrorCode::ShuttingDown => "shutting_down",
            ErrorCode::UnknownJob => "unknown_job",
            ErrorCode::UnknownVolume => "unknown_volume",
            ErrorCode::ShapeMismatch => "shape_mismatch",
            ErrorCode::InvalidState => "invalid_state",
            ErrorCode::Unavailable => "unavailable",
            ErrorCode::Internal => "internal",
        }
    }

    /// Parse a wire code. Unknown codes decode to `None`; clients treat
    /// them as [`ErrorCode::Internal`] (forward compatibility: a newer
    /// daemon may grow the registry).
    pub fn parse(s: &str) -> Option<ErrorCode> {
        Some(match s {
            "bad_request" => ErrorCode::BadRequest,
            "queue_full" => ErrorCode::QueueFull,
            "shutting_down" => ErrorCode::ShuttingDown,
            "unknown_job" => ErrorCode::UnknownJob,
            "unknown_volume" => ErrorCode::UnknownVolume,
            "shape_mismatch" => ErrorCode::ShapeMismatch,
            "invalid_state" => ErrorCode::InvalidState,
            "unavailable" => ErrorCode::Unavailable,
            "internal" => ErrorCode::Internal,
            _ => return None,
        })
    }

    /// Whether retrying the same request later can succeed without the
    /// client changing anything.
    pub fn retryable(&self) -> bool {
        matches!(
            self,
            ErrorCode::QueueFull | ErrorCode::ShuttingDown | ErrorCode::Unavailable
        )
    }

    /// Process exit code for the CLI (sysexits.h conventions), so scripts
    /// driving `claire submit` can branch without parsing stderr:
    /// retryable codes exit 75 (EX_TEMPFAIL), malformed requests 64
    /// (EX_USAGE), data-shape problems 65 (EX_DATAERR), missing
    /// jobs/volumes 66 (EX_NOINPUT), transport failures 69
    /// (EX_UNAVAILABLE), internal failures 70 (EX_SOFTWARE).
    pub fn exit_code(&self) -> i32 {
        match self {
            ErrorCode::QueueFull | ErrorCode::ShuttingDown => 75,
            ErrorCode::BadRequest => 64,
            ErrorCode::ShapeMismatch | ErrorCode::InvalidState => 65,
            ErrorCode::UnknownJob | ErrorCode::UnknownVolume => 66,
            ErrorCode::Unavailable => 69,
            ErrorCode::Internal => 70,
        }
    }
}

impl std::fmt::Display for ErrorCode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Unified error type across runtime, solver, data and coordinator layers.
#[derive(Error, Debug)]
pub enum Error {
    #[error("XLA/PJRT error: {0}")]
    Xla(#[from] xla::Error),

    #[error("I/O error: {0}")]
    Io(#[from] std::io::Error),

    #[error("manifest error: {0}")]
    Manifest(String),

    #[error("artifact not found: op={op} variant={variant} n={n} (run `make artifacts`)")]
    ArtifactNotFound { op: String, variant: String, n: usize },

    #[error("shape mismatch for {what}: expected {expected} elements, got {got}")]
    ShapeMismatch { what: String, expected: usize, got: usize },

    #[error("solver error: {0}")]
    Solver(String),

    /// A solve interrupted cooperatively at an iteration boundary
    /// (`SolveCx` cancellation). Carries the iterations completed before
    /// the interrupt so callers (the serve scheduler, batch drivers) can
    /// report partial work instead of discarding it.
    #[error("solve cancelled after {} iterations", history.len())]
    Cancelled { history: Vec<crate::registration::solver::IterRecord> },

    #[error("config error: {0}")]
    Config(String),

    #[error("data error: {0}")]
    Data(String),

    #[error("JSON parse error at byte {at}: {msg}")]
    Json { at: usize, msg: String },

    #[error("serve error: {0}")]
    Serve(String),

    /// A classified wire-protocol failure. Displays with the legacy
    /// `serve error: ` prefix because every pre-taxonomy daemon error on
    /// these paths was an `Error::Serve` — the v1 wire renders
    /// `to_string()` into the `error` field, and those bytes are a compat
    /// surface. The code travels in the structured fields of a v2
    /// response.
    #[error("serve error: {msg}")]
    Wire { code: ErrorCode, msg: String },
}

impl Error {
    /// Build a classified wire error.
    pub fn wire(code: ErrorCode, msg: impl Into<String>) -> Error {
        Error::Wire { code, msg: msg.into() }
    }

    /// Classify any error for the wire: explicit codes pass through,
    /// everything else maps onto the closest registry entry.
    pub fn code(&self) -> ErrorCode {
        match self {
            Error::Wire { code, .. } => *code,
            Error::Json { .. } => ErrorCode::BadRequest,
            Error::ShapeMismatch { .. } => ErrorCode::ShapeMismatch,
            Error::Io(_) => ErrorCode::Unavailable,
            _ => ErrorCode::Internal,
        }
    }

    /// CLI process exit code for this error. Wire errors use their code's
    /// mapping; transport failures (I/O, client-side serve errors) exit 69
    /// (EX_UNAVAILABLE); local usage errors exit 64 (EX_USAGE); anything
    /// else keeps the generic failure exit 1.
    pub fn exit_code(&self) -> i32 {
        match self {
            Error::Wire { code, .. } => code.exit_code(),
            Error::Io(_) | Error::Serve(_) => 69,
            Error::Config(_) => 64,
            _ => 1,
        }
    }
}

pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codes_roundtrip_their_string_forms() {
        for code in [
            ErrorCode::BadRequest,
            ErrorCode::QueueFull,
            ErrorCode::ShuttingDown,
            ErrorCode::UnknownJob,
            ErrorCode::UnknownVolume,
            ErrorCode::ShapeMismatch,
            ErrorCode::InvalidState,
            ErrorCode::Unavailable,
            ErrorCode::Internal,
        ] {
            assert_eq!(ErrorCode::parse(code.as_str()), Some(code));
        }
        assert_eq!(ErrorCode::parse("not_a_code"), None);
    }

    #[test]
    fn retryable_and_exit_codes_follow_the_registry() {
        assert!(ErrorCode::QueueFull.retryable());
        assert!(ErrorCode::ShuttingDown.retryable());
        assert!(ErrorCode::Unavailable.retryable());
        assert!(!ErrorCode::BadRequest.retryable());
        assert!(!ErrorCode::UnknownVolume.retryable());
        // The satellite contract: scripts branch on 75 / 64 / 69.
        assert_eq!(ErrorCode::QueueFull.exit_code(), 75);
        assert_eq!(ErrorCode::BadRequest.exit_code(), 64);
        assert_eq!(ErrorCode::Unavailable.exit_code(), 69);
        assert_eq!(ErrorCode::Internal.exit_code(), 70);
    }

    #[test]
    fn wire_errors_keep_the_legacy_serve_prefix() {
        // Byte-compat: every pre-taxonomy error on these paths displayed
        // as `Error::Serve` ("serve error: …"), and the v1 wire renders
        // Display into the `error` field — so v1 clients see exactly the
        // strings they always did; the code travels only in structured v2
        // fields.
        let e = Error::wire(ErrorCode::QueueFull, "queue full (2 waiting, cap 2)");
        assert_eq!(e.to_string(), "serve error: queue full (2 waiting, cap 2)");
        assert_eq!(e.code(), ErrorCode::QueueFull);
        assert_eq!(e.exit_code(), 75);
    }

    #[test]
    fn unclassified_errors_map_onto_the_registry() {
        assert_eq!(Error::Serve("x".into()).code(), ErrorCode::Internal);
        assert_eq!(
            Error::Json { at: 0, msg: "bad".into() }.code(),
            ErrorCode::BadRequest
        );
        assert_eq!(
            Error::ShapeMismatch { what: "v".into(), expected: 8, got: 7 }.code(),
            ErrorCode::ShapeMismatch
        );
        assert_eq!(Error::Serve("cannot reach daemon".into()).exit_code(), 69);
        assert_eq!(Error::Config("bad flag".into()).exit_code(), 64);
    }
}
