//! Error types for the CLAIRE coordinator.

use thiserror::Error;

/// Unified error type across runtime, solver, data and coordinator layers.
#[derive(Error, Debug)]
pub enum Error {
    #[error("XLA/PJRT error: {0}")]
    Xla(#[from] xla::Error),

    #[error("I/O error: {0}")]
    Io(#[from] std::io::Error),

    #[error("manifest error: {0}")]
    Manifest(String),

    #[error("artifact not found: op={op} variant={variant} n={n} (run `make artifacts`)")]
    ArtifactNotFound { op: String, variant: String, n: usize },

    #[error("shape mismatch for {what}: expected {expected} elements, got {got}")]
    ShapeMismatch { what: String, expected: usize, got: usize },

    #[error("solver error: {0}")]
    Solver(String),

    #[error("config error: {0}")]
    Config(String),

    #[error("data error: {0}")]
    Data(String),

    #[error("JSON parse error at byte {at}: {msg}")]
    Json { at: usize, msg: String },

    #[error("serve error: {0}")]
    Serve(String),
}

pub type Result<T> = std::result::Result<T, Error>;
