//! Mini property-based testing framework (proptest is unavailable offline).
//!
//! Provides seeded generators and a `check` runner that reports the failing
//! case number and seed on failure so tests are reproducible. No shrinking;
//! generators are kept small instead, which is adequate for the invariants
//! tested in this crate (field-algebra identities, scheduler invariants,
//! FFT/interp kernel properties).

use crate::util::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 64, seed: 0xC1A1_2E } // "CLAIRE"
    }
}

/// Run `prop` against `cases` generated inputs; panic with diagnostics on
/// the first failure. `gen` receives a per-case RNG.
pub fn check<T: std::fmt::Debug>(
    cfg: Config,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    let mut root = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let mut r = root.split();
        let input = gen(&mut r);
        if !prop(&input) {
            panic!(
                "property failed at case {case} (seed {:#x}): input = {:?}",
                cfg.seed, input
            );
        }
    }
}

/// Like `check` but the property returns `Result<(), String>` for richer
/// failure messages.
pub fn check_msg<T: std::fmt::Debug>(
    cfg: Config,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let mut root = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let mut r = root.split();
        let input = gen(&mut r);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed at case {case} (seed {:#x}): {msg}\ninput = {:?}",
                cfg.seed, input
            );
        }
    }
}

// -- Common generators ------------------------------------------------------

/// Vector of f32 in [lo, hi].
pub fn vec_f32(r: &mut Rng, len: usize, lo: f32, hi: f32) -> Vec<f32> {
    (0..len).map(|_| r.uniform_f32(lo, hi)).collect()
}

/// Power-of-two size in [lo, hi] (both must be powers of two).
pub fn pow2(r: &mut Rng, lo: usize, hi: usize) -> usize {
    let lo_b = lo.trailing_zeros();
    let hi_b = hi.trailing_zeros();
    1 << (lo_b + r.below((hi_b - lo_b + 1) as u64) as u32)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check(Config::default(), |r| r.uniform(), |x| (0.0..1.0).contains(x));
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        check(Config { cases: 16, seed: 1 }, |r| r.below(10), |&x| x < 5);
    }

    #[test]
    fn pow2_in_range() {
        let mut r = Rng::new(2);
        for _ in 0..100 {
            let n = pow2(&mut r, 4, 64);
            assert!(n.is_power_of_two() && (4..=64).contains(&n));
        }
    }
}
