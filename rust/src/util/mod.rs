//! Small self-contained substrates: the offline build has no clap /
//! criterion / proptest / rand / serde, so this crate carries its own
//! equivalents (see DESIGN.md section 4).

pub mod args;
pub mod base64;
pub mod bench;
pub mod json;
pub mod prop;
pub mod rng;
pub mod sync;
