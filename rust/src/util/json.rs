//! Minimal JSON parser + serializer (manifest, wire protocol, sidecars).
//!
//! The offline image has no `serde`; the artifact manifest, the serve wire
//! protocol, and the volume sidecars are the only JSON we touch, so a small
//! recursive-descent parser over a value enum is the right size. Supports
//! the full JSON grammar minus exotic number forms. `render` is the inverse
//! used by the daemon's newline-delimited protocol and the job journal.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Escape a string for embedding inside a JSON string literal (no quotes
/// added). Shared by the serializer, the volume sidecars in `data/io.rs`,
/// and the serve wire protocol.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json> {
        let mut p = Parser { bytes: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    /// Strict non-negative integer: rejects fractional and negative
    /// numbers instead of truncating/clamping like `as_usize`. Use for
    /// identifiers, where 1.9 must not silently become job 1.
    pub fn as_index(&self) -> Option<u64> {
        match self {
            Json::Num(x) if x.fract() == 0.0 && *x >= 0.0 && *x < 9e15 => Some(*x as u64),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Build an object from (key, value) pairs (keys are sorted by BTreeMap;
    /// the wire protocol is order-insensitive).
    pub fn object<'a>(pairs: impl IntoIterator<Item = (&'a str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    /// Serialize compactly (one line, no trailing newline). Integral finite
    /// numbers render without a fractional part so ids/counts round-trip
    /// through `as_usize`; non-finite numbers render as `null`.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.render_into(&mut out);
        out
    }

    fn render_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if !x.is_finite() {
                    out.push_str("null");
                } else if x.fract() == 0.0 && x.abs() < 9e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                out.push_str(&escape(s));
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    e.render_into(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('"');
                    out.push_str(&escape(k));
                    out.push_str("\":");
                    v.render_into(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Json { at: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected literal {s}")))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad \\u"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Re-decode UTF-8 multibyte sequences.
                    let start = self.pos - 1;
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    // Clamp against a sequence truncated at end-of-line so
                    // the slice below stays in bounds; from_utf8 then
                    // rejects the partial sequence as bad utf8.
                    self.pos = (start + len).min(self.bytes.len());
                    let s = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|_| self.err("bad utf8"))?;
                    out.push_str(s);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[2].get("b").unwrap().as_str(), Some("c"));
        assert!(v.get("d").unwrap().as_obj().unwrap().is_empty());
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\nb\t\"c\" A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"c\" A"));
    }

    #[test]
    fn reject_trailing() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\"}").is_err());
    }

    #[test]
    fn parse_manifest_shape() {
        let v = Json::parse(
            r#"{"artifacts": {"grad_fd8__x__n16": {"file": "f.hlo.txt",
                "inputs": [{"name": "f", "shape": [16,16,16], "dtype": "f32"}],
                "n": 16}}}"#,
        )
        .unwrap();
        let art = v.get("artifacts").unwrap().get("grad_fd8__x__n16").unwrap();
        assert_eq!(art.get("n").unwrap().as_usize(), Some(16));
        let ins = art.get("inputs").unwrap().as_arr().unwrap();
        let shape: Vec<usize> =
            ins[0].get("shape").unwrap().as_arr().unwrap().iter().map(|x| x.as_usize().unwrap()).collect();
        assert_eq!(shape, vec![16, 16, 16]);
    }

    #[test]
    fn parse_unicode_multibyte() {
        let v = Json::parse("\"caf\u{e9} \u{1F600}\"").unwrap();
        assert_eq!(v.as_str(), Some("café 😀"));
    }

    #[test]
    fn escape_covers_control_and_quotes() {
        assert_eq!(escape("a\"b"), "a\\\"b");
        assert_eq!(escape("a\\b"), "a\\\\b");
        assert_eq!(escape("a\nb\tc"), "a\\nb\\tc");
        assert_eq!(escape("\u{1}"), "\\u0001");
        assert_eq!(escape("plain"), "plain");
    }

    #[test]
    fn render_parse_roundtrip() {
        let v = Json::object([
            ("id", Json::num(42.0)),
            ("name", Json::str("a\"b\\c\nd")),
            ("ok", Json::Bool(true)),
            ("xs", Json::Arr(vec![Json::num(1.5), Json::Null])),
        ]);
        let s = v.render();
        assert_eq!(Json::parse(&s).unwrap(), v);
        // Integral numbers render without a fraction (ids survive as_usize).
        assert!(s.contains("\"id\":42"));
    }

    #[test]
    fn render_nonfinite_as_null() {
        assert_eq!(Json::num(f64::NAN).render(), "null");
        assert_eq!(Json::num(f64::INFINITY).render(), "null");
    }

    #[test]
    fn as_index_is_strict() {
        assert_eq!(Json::num(7.0).as_index(), Some(7));
        assert_eq!(Json::num(0.0).as_index(), Some(0));
        assert_eq!(Json::num(1.9).as_index(), None);
        assert_eq!(Json::num(-1.0).as_index(), None);
        assert_eq!(Json::str("7").as_index(), None);
        // as_usize keeps its lenient truncating behavior.
        assert_eq!(Json::num(1.9).as_usize(), Some(1));
    }
}
