//! Minimal JSON parser (manifest files only).
//!
//! The offline image has no `serde`; the artifact manifest is the only JSON
//! we consume, so a small recursive-descent parser over a value enum is the
//! right size. Supports the full JSON grammar minus exotic number forms.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json> {
        let mut p = Parser { bytes: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Json { at: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected literal {s}")))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad \\u"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Re-decode UTF-8 multibyte sequences.
                    let start = self.pos - 1;
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    self.pos = start + len;
                    let s = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|_| self.err("bad utf8"))?;
                    out.push_str(s);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[2].get("b").unwrap().as_str(), Some("c"));
        assert!(v.get("d").unwrap().as_obj().unwrap().is_empty());
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\nb\t\"c\" A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"c\" A"));
    }

    #[test]
    fn reject_trailing() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\"}").is_err());
    }

    #[test]
    fn parse_manifest_shape() {
        let v = Json::parse(
            r#"{"artifacts": {"grad_fd8__x__n16": {"file": "f.hlo.txt",
                "inputs": [{"name": "f", "shape": [16,16,16], "dtype": "f32"}],
                "n": 16}}}"#,
        )
        .unwrap();
        let art = v.get("artifacts").unwrap().get("grad_fd8__x__n16").unwrap();
        assert_eq!(art.get("n").unwrap().as_usize(), Some(16));
        let ins = art.get("inputs").unwrap().as_arr().unwrap();
        let shape: Vec<usize> =
            ins[0].get("shape").unwrap().as_arr().unwrap().iter().map(|x| x.as_usize().unwrap()).collect();
        assert_eq!(shape, vec![16, 16, 16]);
    }

    #[test]
    fn parse_unicode_multibyte() {
        let v = Json::parse("\"caf\u{e9} \u{1F600}\"").unwrap();
        assert_eq!(v.as_str(), Some("café 😀"));
    }
}
