//! Sync-primitive shim: the single import point for every concurrent
//! module in the crate.
//!
//! Normal builds re-export `std::sync` + `std::thread` unchanged, so this
//! module is zero-cost. Under `RUSTFLAGS="--cfg loom"` the same names
//! resolve to [loom](https://docs.rs/loom)'s model-checked replacements,
//! which lets `tests/loom_serve.rs` exhaustively interleave the scheduler,
//! event bus, dedup admission, and shutdown paths without any per-test
//! code swap. The custom invariant lint (`cargo xtask lint`, mirrored by
//! `scripts/lint_invariants.py`) enforces that no module outside this file
//! imports `std::sync::{Mutex, Condvar, RwLock}`, `std::sync::atomic`, or
//! `std::thread` directly — see DESIGN.md "Concurrency model & analysis".
//!
//! Deliberate deviations from a pure re-export:
//!
//! - `Arc` is always `std::sync::Arc`, even under loom. The tree relies on
//!   unsized coercions (`Arc<str>`, `Arc<dyn SolveObserver>`) and
//!   `From<String>` impls that loom's tracking `Arc` does not provide, and
//!   loom establishes causality through `Mutex`/`Condvar`/atomics — which
//!   *are* swapped — so models lose nothing.
//! - Under loom, `thread::scope` remains `std::thread::scope` (loom has no
//!   scoped threads). The scoped paths (`coordinator/service.rs`) are not
//!   exercised by loom models; they only need to compile.
//! - Under loom, `thread::sleep` is modelled as `loom::thread::yield_now()`:
//!   sleeps are scheduling hints, never correctness, per the lint's
//!   lock-order rules.

/// Memory-ordering policy (enforced by convention, documented in
/// DESIGN.md "Concurrency model & analysis"):
///
/// - **Signal flags** (cancel flags, router shutdown, pool up/down):
///   `store(Release)` by the signaller, `load(Acquire)` by the observer,
///   `swap(AcqRel)` when the signaller also needs the previous value.
/// - **Config cells** (`coalesce_b`, `coalesce_ms`): `Relaxed` — they are
///   self-contained values; no other memory is published through them.
/// - **Counters** (`OpRegistry::hits`/`compiles`): `Relaxed` — monotonic
///   statistics, read only for reporting.
#[cfg(not(loom))]
pub use std::sync::atomic;
#[cfg(not(loom))]
pub use std::sync::{Condvar, Mutex, MutexGuard};
#[cfg(not(loom))]
pub use std::thread;

#[cfg(loom)]
pub use loom::sync::atomic;
#[cfg(loom)]
pub use loom::sync::{Condvar, Mutex, MutexGuard};

/// See the module docs: `Arc` stays `std::sync::Arc` under loom too.
pub use std::sync::Arc;

#[cfg(loom)]
pub mod thread {
    pub use loom::thread::*;
    // Explicit items shadow the glob: these fill loom's API gaps.
    pub use std::thread::{scope, Scope, ScopedJoinHandle};

    /// Sleeps are scheduling hints in this crate, never correctness:
    /// under the model checker a sleep is just a preemption point.
    pub fn sleep(_dur: std::time::Duration) {
        loom::thread::yield_now();
    }
}
