//! Deterministic pseudo-random number generation (SplitMix64).
//!
//! The image has no `rand` crate offline; this is a small, well-understood
//! generator (Steele et al., "Fast splittable pseudorandom number
//! generators") that is plenty for synthetic data generation and property
//! tests. Reproducibility across runs matters more here than statistical
//! perfection.

/// SplitMix64 PRNG. Deterministic, seedable, splittable.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15) }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Derive an independent generator (for per-job/per-thread streams).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    /// Uniform f64 in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform f32 in [lo, hi).
    pub fn uniform_f32(&mut self, lo: f32, hi: f32) -> f32 {
        self.uniform_in(lo as f64, hi as f64) as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire-style rejection-free bound is overkill here; modulo bias is
        // negligible for n << 2^64 in our use (data gen, prop tests).
        self.next_u64() % n.max(1)
    }

    /// Standard normal (Box-Muller).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-300);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.uniform();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(7);
        let mean: f64 = (0..100_000).map(|_| r.uniform()).sum::<f64>() / 100_000.0;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let xs: Vec<f64> = (0..100_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_bound() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn split_streams_differ() {
        let mut a = Rng::new(5);
        let mut b = a.split();
        let xs: Vec<u64> = (0..32).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..32).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
