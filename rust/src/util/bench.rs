//! Minimal benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` runs the `[[bench]]` binaries with `harness = false`; each
//! bench uses this module: warmup, fixed sample count, robust statistics
//! (median + MAD), and aligned table output matching the paper's tables.

use std::time::Instant;

/// Result of one timed benchmark.
#[derive(Clone, Debug)]
pub struct Sample {
    pub name: String,
    /// Median seconds per iteration.
    pub median_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    /// Median absolute deviation, seconds.
    pub mad_s: f64,
    pub iters: usize,
}

impl Sample {
    pub fn throughput_gbs(&self, bytes: usize) -> f64 {
        bytes as f64 / self.median_s / 1e9
    }
}

/// Benchmark runner with warmup and sample statistics.
pub struct Bench {
    pub warmup: usize,
    pub samples: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup: 2, samples: 7 }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Bench { warmup: 1, samples: 3 }
    }

    /// Time `f` (one call per sample) and return robust statistics.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> Sample {
        for _ in 0..self.warmup {
            f();
        }
        let mut times = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            f();
            times.push(t0.elapsed().as_secs_f64());
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = times[times.len() / 2];
        let mut devs: Vec<f64> = times.iter().map(|t| (t - median).abs()).collect();
        devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Sample {
            name: name.to_string(),
            median_s: median,
            min_s: times[0],
            max_s: *times.last().unwrap(),
            mad_s: devs[devs.len() / 2],
            iters: self.samples,
        }
    }
}

/// Format seconds in engineering style (matches paper tables: 1.5, 1.1e1).
pub fn fmt_time(s: f64) -> String {
    if s == 0.0 {
        return "0".into();
    }
    let exp = s.abs().log10().floor() as i32;
    if (-1..=2).contains(&exp) {
        format!("{s:.2}")
    } else {
        format!("{s:.1e}")
    }
}

/// Simple aligned table printer for bench outputs.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut w = vec![0usize; ncol];
        for (i, h) in self.headers.iter().enumerate() {
            w[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], w: &[usize]| -> String {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!("{:<width$}  ", c, width = w[i]));
            }
            line.trim_end().to_string() + "\n"
        };
        out.push_str(&fmt_row(&self.headers, &w));
        out.push_str(&format!("{}\n", "-".repeat(w.iter().sum::<usize>() + 2 * (ncol - 1))));
        for row in &self.rows {
            out.push_str(&fmt_row(row, &w));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_orders() {
        let b = Bench::quick();
        let s = b.run("spin", || {
            let mut acc = 0u64;
            for i in 0..10_000 {
                acc = acc.wrapping_add(i);
            }
            std::hint::black_box(acc);
        });
        assert!(s.min_s <= s.median_s && s.median_s <= s.max_s);
        assert!(s.median_s > 0.0);
    }

    #[test]
    fn fmt_time_styles() {
        assert_eq!(fmt_time(1.53), "1.53");
        assert_eq!(fmt_time(0.0), "0");
        assert!(fmt_time(1.1e-4).contains('e'));
        assert!(fmt_time(84.0).contains("84"));
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["N", "time", "BW"]);
        t.row(&["64^3".into(), "1.5".into(), "50".into()]);
        t.row(&["256^3".into(), "8.4e1".into(), "56".into()]);
        let s = t.render();
        assert!(s.contains("N"));
        assert!(s.lines().count() == 4);
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn table_rejects_bad_row() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into()]);
    }
}
