//! Standard base64 (RFC 4648, with padding) — the volume payload encoding
//! for the serve data plane's `upload` verb. The offline image has no
//! `base64` crate; encode/decode here are the only binary-in-JSON bridge
//! the wire protocol needs, so a table-driven implementation is the right
//! size. Strict decode: non-alphabet bytes, bad lengths and bad padding
//! are errors, never silently skipped — a corrupted volume upload must be
//! rejected at the protocol boundary, not produce a garbage image.

use crate::error::{Error, Result};

const ALPHABET: &[u8; 64] = b"ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnopqrstuvwxyz0123456789+/";

/// Value of one alphabet byte, or 255 for bytes outside the alphabet.
fn decode_one(b: u8) -> u8 {
    match b {
        b'A'..=b'Z' => b - b'A',
        b'a'..=b'z' => b - b'a' + 26,
        b'0'..=b'9' => b - b'0' + 52,
        b'+' => 62,
        b'/' => 63,
        _ => 255,
    }
}

/// Encode `bytes` as standard padded base64.
pub fn encode(bytes: &[u8]) -> String {
    let mut out = String::with_capacity(bytes.len() * 4 / 3 + 4);
    encode_into(bytes, &mut out);
    out
}

/// Encode `bytes` as standard padded base64, appending to `out`. Lets the
/// serve data plane render a volume payload straight into a protocol line
/// without holding a second base64 `String` alongside it (the upload hot
/// path peaks at one transient copy of the payload).
pub fn encode_into(bytes: &[u8], out: &mut String) {
    out.reserve(bytes.len() * 4 / 3 + 4);
    for chunk in bytes.chunks(3) {
        let b0 = chunk[0] as u32;
        let b1 = chunk.get(1).copied().unwrap_or(0) as u32;
        let b2 = chunk.get(2).copied().unwrap_or(0) as u32;
        let v = (b0 << 16) | (b1 << 8) | b2;
        out.push(ALPHABET[(v >> 18) as usize & 63] as char);
        out.push(ALPHABET[(v >> 12) as usize & 63] as char);
        out.push(if chunk.len() > 1 { ALPHABET[(v >> 6) as usize & 63] as char } else { '=' });
        out.push(if chunk.len() > 2 { ALPHABET[v as usize & 63] as char } else { '=' });
    }
}

/// Decode standard padded base64. Errors on length not a multiple of 4,
/// non-alphabet characters, misplaced padding, or nonzero trailing bits.
pub fn decode(s: &str) -> Result<Vec<u8>> {
    let bytes = s.as_bytes();
    if bytes.len() % 4 != 0 {
        return Err(Error::Data(format!("base64 length {} is not a multiple of 4", bytes.len())));
    }
    let mut out = Vec::with_capacity(bytes.len() / 4 * 3);
    for (ci, chunk) in bytes.chunks_exact(4).enumerate() {
        let last = ci + 1 == bytes.len() / 4;
        // Padding is only legal in the final quantum, as '=' or '=='.
        let pad = chunk.iter().filter(|&&b| b == b'=').count();
        let data_len = match (last, pad, chunk[2] == b'=', chunk[3] == b'=') {
            (_, 0, _, _) => 3,
            (true, 1, false, true) => 2,
            (true, 2, true, true) => 1,
            _ => return Err(Error::Data("base64: misplaced padding".into())),
        };
        let mut v: u32 = 0;
        for &b in &chunk[..data_len + 1] {
            let d = decode_one(b);
            if d == 255 {
                return Err(Error::Data(format!("base64: invalid byte 0x{b:02x}")));
            }
            v = (v << 6) | d as u32;
        }
        // Left-align to the 24-bit quantum and check the dropped bits are
        // zero (canonical encoding; rejects truncated-then-padded tails).
        v <<= 6 * (3 - data_len);
        if data_len < 3 && v & ((1 << (8 * (3 - data_len))) - 1) != 0 {
            return Err(Error::Data("base64: nonzero trailing bits".into()));
        }
        out.push((v >> 16) as u8);
        if data_len > 1 {
            out.push((v >> 8) as u8);
        }
        if data_len > 2 {
            out.push(v as u8);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rfc4648_vectors() {
        for (plain, enc) in [
            ("", ""),
            ("f", "Zg=="),
            ("fo", "Zm8="),
            ("foo", "Zm9v"),
            ("foob", "Zm9vYg=="),
            ("fooba", "Zm9vYmE="),
            ("foobar", "Zm9vYmFy"),
        ] {
            assert_eq!(encode(plain.as_bytes()), enc);
            assert_eq!(decode(enc).unwrap(), plain.as_bytes());
        }
    }

    #[test]
    fn binary_roundtrip() {
        let mut rng = crate::util::rng::Rng::new(7);
        for len in [0usize, 1, 2, 3, 4, 255, 256, 1023] {
            let data: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
            assert_eq!(decode(&encode(&data)).unwrap(), data, "len {len}");
        }
    }

    #[test]
    fn encode_into_appends() {
        let mut out = String::from("prefix:");
        encode_into(b"foobar", &mut out);
        assert_eq!(out, "prefix:Zm9vYmFy");
    }

    #[test]
    fn strict_decode_rejects_garbage() {
        assert!(decode("a").is_err(), "bad length");
        assert!(decode("ab!c").is_err(), "bad byte");
        assert!(decode("ab=c").is_err(), "interior padding");
        assert!(decode("=abc").is_err(), "leading padding");
        assert!(decode("Zg==Zg==").is_err(), "padding before final quantum");
        assert!(decode("Zh==").is_err(), "nonzero trailing bits");
        assert!(decode("Zm9=").is_err(), "nonzero trailing bits (2-byte)");
    }
}
