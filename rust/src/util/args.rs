//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments,
//! and generates usage text from registered options.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Declarative description of one option for usage text.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<String>,
    pub is_flag: bool,
}

/// Parsed command line: options + positionals, with typed accessors.
#[derive(Clone, Debug, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (no program name).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, specs: &[OptSpec]) -> Result<Args> {
        let mut out = Args::default();
        let flag_names: Vec<&str> =
            specs.iter().filter(|s| s.is_flag).map(|s| s.name).collect();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&rest) {
                    out.flags.push(rest.to_string());
                } else if let Some(v) = it.peek() {
                    if v.starts_with("--") {
                        out.flags.push(rest.to_string());
                    } else {
                        out.opts.insert(rest.to_string(), it.next().unwrap());
                    }
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        // Reject unknown options when specs are provided.
        if !specs.is_empty() {
            let known: Vec<&str> = specs.iter().map(|s| s.name).collect();
            for k in out.opts.keys().map(String::as_str).chain(out.flags.iter().map(String::as_str))
            {
                if !known.contains(&k) {
                    return Err(Error::Config(format!(
                        "unknown option --{k}\n{}",
                        usage(specs)
                    )));
                }
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(String::as_str)
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{name} expects an integer, got '{v}'"))),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{name} expects a number, got '{v}'"))),
        }
    }

    /// Comma-separated list of usize, e.g. `--sizes 16,32,64`.
    pub fn get_usize_list(&self, name: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.trim()
                        .parse()
                        .map_err(|_| Error::Config(format!("--{name}: bad integer '{s}'")))
                })
                .collect(),
        }
    }

    pub fn get_str_list(&self, name: &str, default: &[&str]) -> Vec<String> {
        match self.get(name) {
            None => default.iter().map(|s| s.to_string()).collect(),
            Some(v) => v.split(',').filter(|s| !s.is_empty()).map(|s| s.trim().to_string()).collect(),
        }
    }
}

/// Render usage text from option specs.
pub fn usage(specs: &[OptSpec]) -> String {
    let mut s = String::from("options:\n");
    for spec in specs {
        let d = spec
            .default
            .as_ref()
            .map(|d| format!(" [default: {d}]"))
            .unwrap_or_default();
        s.push_str(&format!("  --{:<18} {}{}\n", spec.name, spec.help, d));
    }
    s
}

/// Helper to build an OptSpec concisely.
pub fn opt(name: &'static str, help: &'static str, default: &str) -> OptSpec {
    OptSpec { name, help, default: Some(default.to_string()), is_flag: false }
}

/// Helper to build a boolean flag spec.
pub fn flag(name: &'static str, help: &'static str) -> OptSpec {
    OptSpec { name, help, default: None, is_flag: true }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parse_kv_and_flags() {
        let specs = [opt("n", "size", "16"), flag("verbose", "talk more"), opt("beta", "reg", "5e-4")];
        let a = Args::parse(sv(&["--n", "32", "--verbose", "--beta=1e-3", "pos1"]), &specs).unwrap();
        assert_eq!(a.get_usize("n", 16).unwrap(), 32);
        assert!(a.flag("verbose"));
        assert_eq!(a.get_f64("beta", 0.0).unwrap(), 1e-3);
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn defaults_apply() {
        let specs = [opt("n", "size", "16")];
        let a = Args::parse(sv(&[]), &specs).unwrap();
        assert_eq!(a.get_usize("n", 16).unwrap(), 16);
        assert!(!a.flag("anything"));
    }

    #[test]
    fn unknown_option_rejected() {
        let specs = [opt("n", "size", "16")];
        assert!(Args::parse(sv(&["--bogus", "1"]), &specs).is_err());
    }

    #[test]
    fn lists() {
        let specs = [opt("sizes", "grid sizes", "16"), opt("variants", "kernel variants", "all")];
        let a = Args::parse(sv(&["--sizes", "16,32,64", "--variants", "a,b"]), &specs).unwrap();
        assert_eq!(a.get_usize_list("sizes", &[]).unwrap(), vec![16, 32, 64]);
        assert_eq!(a.get_str_list("variants", &[]), vec!["a", "b"]);
    }

    #[test]
    fn bad_number_is_error() {
        let specs = [opt("n", "size", "16")];
        let a = Args::parse(sv(&["--n", "abc"]), &specs).unwrap();
        assert!(a.get_usize("n", 16).is_err());
    }

    #[test]
    fn trailing_flag_like_value() {
        let specs = [flag("x", "flag"), opt("k", "key", "")];
        let a = Args::parse(sv(&["--k", "--x"]), &specs).unwrap();
        // --k followed by a --flag keeps both as separate options
        assert!(a.flag("k") || a.get("k").is_some());
        assert!(a.flag("x"));
    }
}
