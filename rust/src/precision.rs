//! The mixed-precision policy threaded through all three layers.
//!
//! The paper's headline speedup comes from running the scattered
//! interpolation and the Hessian matvec inner loop at reduced precision
//! (fp16 storage, f32 accumulation) while keeping the gradient, objective
//! and line search in full precision (section 3; CLAIRE's follow-ups keep
//! the same split). `Precision` is the explicit policy object:
//!
//! * `Full`  — f32 everywhere (the seed behavior; the default).
//! * `Mixed` — the PCG Hessian matvec executes a reduced-precision artifact
//!   whose per-Newton-iteration caches are marshalled as f16 at the PJRT
//!   boundary; all outer quantities (gradient, objective, line search, PCG
//!   vector algebra) stay f32.
//!
//! The policy flows L1 -> L3: `python/compile` lowers reduced-precision
//! artifacts (`*__mixed` keys, per-tensor `dtype` manifest entries),
//! `runtime/` marshals literals by dtype and caches compiled operators per
//! `(op, variant, n, precision)`, `registration/solver.rs` picks the matvec
//! artifact by policy, and `serve`/CLI carry a `precision` job field.

use crate::error::{Error, Result};

/// Solver precision policy (see module docs).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord)]
pub enum Precision {
    /// f32 storage and compute everywhere.
    #[default]
    Full,
    /// fp16 storage for the Hessian-matvec caches and interpolation inner
    /// loop, f32 accumulation and outer quantities.
    Mixed,
}

impl Precision {
    pub fn as_str(&self) -> &'static str {
        match self {
            Precision::Full => "full",
            Precision::Mixed => "mixed",
        }
    }

    pub fn parse(s: &str) -> Result<Precision> {
        match s {
            "full" => Ok(Precision::Full),
            "mixed" => Ok(Precision::Mixed),
            other => Err(Error::Config(format!(
                "unknown precision '{other}' (expected 'full' or 'mixed')"
            ))),
        }
    }
}

impl std::fmt::Display for Precision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip_and_default() {
        assert_eq!(Precision::default(), Precision::Full);
        for p in [Precision::Full, Precision::Mixed] {
            assert_eq!(Precision::parse(p.as_str()).unwrap(), p);
        }
        assert!(Precision::parse("half").is_err());
        assert!(Precision::parse("").is_err());
        assert_eq!(format!("{}", Precision::Mixed), "mixed");
    }
}
