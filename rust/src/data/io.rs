//! Raw volume IO: little-endian f32/u16 volumes with a JSON sidecar.
//!
//! Stands in for NIfTI in the original pipeline; enough to dump and reload
//! registration inputs/outputs (mismatch maps, det F fields, label maps)
//! for the qualitative Fig-5/6 style inspection.

use std::fs;
use std::io::{Read, Write};
use std::path::Path;

use crate::error::{Error, Result};
use crate::field::Field3;
use crate::util::json::Json;

/// Serialize f32 samples little-endian — the `.f32` on-disk format and the
/// serve data plane's wire payload format (base64-wrapped there).
pub fn f32s_to_le_bytes(data: &[f32]) -> Vec<u8> {
    let mut bytes = Vec::with_capacity(data.len() * 4);
    for &x in data {
        bytes.extend_from_slice(&x.to_le_bytes());
    }
    bytes
}

/// Inverse of [`f32s_to_le_bytes`]; errors unless the byte count is a
/// multiple of 4.
pub fn f32s_from_le_bytes(bytes: &[u8]) -> Result<Vec<f32>> {
    if bytes.len() % 4 != 0 {
        return Err(Error::Data(format!(
            "f32 volume payload of {} bytes is not a multiple of 4",
            bytes.len()
        )));
    }
    Ok(bytes.chunks_exact(4).map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]])).collect())
}

/// Write a scalar field as `<path>.f32` + `<path>.json` metadata.
pub fn write_field(path: &Path, f: &Field3, desc: &str) -> Result<()> {
    fs::File::create(path.with_extension("f32"))?.write_all(&f32s_to_le_bytes(&f.data))?;
    let meta = format!(
        "{{\"n\": {}, \"dtype\": \"f32\", \"order\": \"x1x2x3\", \"desc\": \"{}\"}}\n",
        f.n,
        crate::util::json::escape(desc)
    );
    fs::write(path.with_extension("json"), meta)?;
    Ok(())
}

/// Read a scalar field written by `write_field`.
pub fn read_field(path: &Path) -> Result<Field3> {
    let meta = fs::read_to_string(path.with_extension("json"))?;
    let j = Json::parse(&meta)?;
    let n = j
        .get("n")
        .and_then(Json::as_usize)
        .ok_or_else(|| Error::Data("missing n in volume meta".into()))?;
    let mut bytes = Vec::new();
    fs::File::open(path.with_extension("f32"))?.read_to_end(&mut bytes)?;
    if bytes.len() != n * n * n * 4 {
        return Err(Error::ShapeMismatch {
            what: format!("{}", path.display()),
            expected: n * n * n * 4,
            got: bytes.len(),
        });
    }
    Field3::from_vec(n, f32s_from_le_bytes(&bytes)?)
}

/// Write a label map as u16 little-endian.
pub fn write_labels(path: &Path, labels: &[u16], n: usize) -> Result<()> {
    let mut bytes = Vec::with_capacity(labels.len() * 2);
    for &x in labels {
        bytes.extend_from_slice(&x.to_le_bytes());
    }
    fs::File::create(path.with_extension("u16"))?.write_all(&bytes)?;
    fs::write(
        path.with_extension("json"),
        format!("{{\"n\": {n}, \"dtype\": \"u16\", \"order\": \"x1x2x3\"}}\n"),
    )?;
    Ok(())
}

/// Read a label map written by `write_labels`.
pub fn read_labels(path: &Path) -> Result<(Vec<u16>, usize)> {
    let meta = fs::read_to_string(path.with_extension("json"))?;
    let j = Json::parse(&meta)?;
    let n = j
        .get("n")
        .and_then(Json::as_usize)
        .ok_or_else(|| Error::Data("missing n in labels meta".into()))?;
    let mut bytes = Vec::new();
    fs::File::open(path.with_extension("u16"))?.read_to_end(&mut bytes)?;
    let labels = bytes.chunks_exact(2).map(|b| u16::from_le_bytes([b[0], b[1]])).collect();
    Ok((labels, n))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn field_roundtrip() {
        let dir = std::env::temp_dir().join("claire_io_test");
        fs::create_dir_all(&dir).unwrap();
        let mut rng = Rng::new(1);
        let f = Field3::from_vec(8, (0..512).map(|_| rng.uniform_f32(-1.0, 1.0)).collect())
            .unwrap();
        let p = dir.join("vol");
        write_field(&p, &f, "test volume").unwrap();
        let g = read_field(&p).unwrap();
        assert_eq!(f, g);
    }

    #[test]
    fn labels_roundtrip() {
        let dir = std::env::temp_dir().join("claire_io_test");
        fs::create_dir_all(&dir).unwrap();
        let labels: Vec<u16> = (0..64u16).collect();
        let p = dir.join("lab");
        write_labels(&p, &labels, 4).unwrap();
        let (got, n) = read_labels(&p).unwrap();
        assert_eq!(n, 4);
        assert_eq!(got, labels);
    }

    #[test]
    fn sidecar_desc_with_hostile_characters_roundtrips() {
        // Backslashes, quotes, and newlines in the description used to
        // produce invalid JSON sidecars (only '"' was rewritten).
        let dir = std::env::temp_dir().join("claire_io_test");
        fs::create_dir_all(&dir).unwrap();
        let f = Field3::zeros(4);
        let p = dir.join("hostile");
        let desc = "path C:\\vol \"quoted\"\nline2\ttabbed";
        write_field(&p, &f, desc).unwrap();
        let meta = fs::read_to_string(p.with_extension("json")).unwrap();
        let j = Json::parse(&meta).unwrap();
        assert_eq!(j.get("desc").and_then(Json::as_str), Some(desc));
        assert_eq!(read_field(&p).unwrap(), f);
    }

    #[test]
    fn le_byte_helpers_roundtrip_and_reject_torn() {
        let xs = vec![0.0f32, -1.5, 3.25e7, f32::MIN_POSITIVE];
        assert_eq!(f32s_from_le_bytes(&f32s_to_le_bytes(&xs)).unwrap(), xs);
        assert!(f32s_from_le_bytes(&[0u8; 6]).is_err());
    }

    #[test]
    fn truncated_file_is_error() {
        let dir = std::env::temp_dir().join("claire_io_test");
        fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad");
        fs::write(p.with_extension("json"), "{\"n\": 8, \"dtype\": \"f32\"}").unwrap();
        fs::write(p.with_extension("f32"), [0u8; 12]).unwrap();
        assert!(read_field(&p).is_err());
    }
}
