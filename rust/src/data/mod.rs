//! Data substrate: synthetic neuroimaging volumes (NIREP substitution) and
//! raw volume IO.

pub mod io;
pub mod synth;
pub mod viz;

pub use synth::{brain_atlas, make_subject, nirep_analog_pair, smooth_random_velocity, Subject};
