//! Terminal volume inspection: ASCII renderings of axial/coronal slices.
//!
//! Stands in for the paper's Figure 5/6 visual panels in a headless
//! environment: `claire register --dump-volumes` writes raw volumes, and
//! this renderer gives an immediate qualitative check (mismatch before vs
//! after, det F hot spots) without leaving the terminal.

use crate::field::Field3;

/// Intensity ramp from dark to bright.
const RAMP: &[u8] = b" .:-=+*#%@";

/// Slicing plane.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Plane {
    /// Fixed x1 (paper's axial view analog).
    Axial,
    /// Fixed x2 (coronal).
    Coronal,
    /// Fixed x3 (sagittal).
    Sagittal,
}

/// Extract one slice as rows of f32 (row-major).
pub fn slice_of(f: &Field3, plane: Plane, index: usize) -> Vec<Vec<f32>> {
    let n = f.n;
    assert!(index < n, "slice index {index} out of range for n={n}");
    let mut rows = Vec::with_capacity(n);
    for a in 0..n {
        let mut row = Vec::with_capacity(n);
        for b in 0..n {
            let v = match plane {
                Plane::Axial => f.at(index, a, b),
                Plane::Coronal => f.at(a, index, b),
                Plane::Sagittal => f.at(a, b, index),
            };
            row.push(v);
        }
        rows.push(row);
    }
    rows
}

/// Render a slice to ASCII with a linear ramp over [min, max] of the slice.
/// `width` columns are downsampled from the grid by nearest sampling.
pub fn render_slice(f: &Field3, plane: Plane, index: usize, width: usize) -> String {
    let rows = slice_of(f, plane, index);
    let n = rows.len();
    let w = width.clamp(8, 160).min(n.max(8));
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for row in &rows {
        for &v in row {
            lo = lo.min(v);
            hi = hi.max(v);
        }
    }
    let span = (hi - lo).max(1e-12);
    let mut out = String::new();
    // Terminal cells are ~2x taller than wide: halve the row count.
    let step = (n as f64 / w as f64).max(1.0);
    let mut a = 0.0;
    while (a as usize) < n {
        let row = &rows[a as usize];
        let mut b = 0.0;
        while (b as usize) < n {
            let v = row[b as usize];
            let t = ((v - lo) / span).clamp(0.0, 1.0);
            let ci = ((t * (RAMP.len() - 1) as f32).round()) as usize;
            out.push(RAMP[ci] as char);
            b += step;
        }
        out.push('\n');
        a += step * 2.0;
    }
    out.push_str(&format!("[{plane:?} slice {index}; range {lo:.3}..{hi:.3}]\n"));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gradient_field(n: usize) -> Field3 {
        let mut f = Field3::zeros(n);
        for i in 0..n {
            for j in 0..n {
                for k in 0..n {
                    f.set(i, j, k, (i + j + k) as f32);
                }
            }
        }
        f
    }

    #[test]
    fn slice_extracts_correct_plane() {
        let f = gradient_field(8);
        let s = slice_of(&f, Plane::Axial, 3);
        assert_eq!(s[2][5], (3 + 2 + 5) as f32);
        let s = slice_of(&f, Plane::Sagittal, 1);
        assert_eq!(s[4][6], (4 + 6 + 1) as f32);
    }

    #[test]
    fn render_has_expected_shape_and_ramp() {
        let f = gradient_field(16);
        let art = render_slice(&f, Plane::Axial, 8, 16);
        assert!(art.contains("slice 8"));
        // Dark at origin corner, bright at far corner.
        let first_line = art.lines().next().unwrap();
        assert!(first_line.starts_with(' ') || first_line.starts_with('.'));
        assert!(art.contains('@'));
    }

    #[test]
    fn constant_field_renders_without_nan() {
        let f = Field3::zeros(8);
        let art = render_slice(&f, Plane::Coronal, 0, 8);
        assert!(!art.contains("NaN"));
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn bad_index_panics() {
        let f = gradient_field(8);
        slice_of(&f, Plane::Axial, 8);
    }
}
