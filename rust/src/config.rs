//! Run configuration: `key = value` files plus CLI overrides.
//!
//! A deliberate TOML subset (serde/toml are unavailable offline): comments
//! with `#`, flat `key = value` pairs, strings unquoted or quoted. This is
//! the launcher's config surface — the analog of CLAIRE's PETSc options
//! files.

use std::collections::BTreeMap;
use std::path::Path;

use crate::error::{Error, Result};
use crate::precision::Precision;
use crate::registration::problem::RegParams;
use crate::request::JobRequest;

/// Flat configuration map with typed accessors.
#[derive(Clone, Debug, Default)]
pub struct Config {
    values: BTreeMap<String, String>,
}

impl Config {
    pub fn parse(text: &str) -> Result<Config> {
        let mut values = BTreeMap::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| {
                Error::Config(format!("line {}: expected key = value, got '{raw}'", lineno + 1))
            })?;
            let v = v.trim().trim_matches('"').trim_matches('\'');
            values.insert(k.trim().to_string(), v.to_string());
        }
        Ok(Config { values })
    }

    pub fn load(path: &Path) -> Result<Config> {
        Self::parse(&std::fs::read_to_string(path)?)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(String::as_str)
    }

    pub fn set(&mut self, key: &str, value: &str) {
        self.values.insert(key.to_string(), value.to_string());
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => {
                v.parse().map_err(|_| Error::Config(format!("{key}: bad number '{v}'")))
            }
        }
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => {
                v.parse().map_err(|_| Error::Config(format!("{key}: bad integer '{v}'")))
            }
        }
    }

    /// Comma-separated list value, e.g. `backends = host:7464,host:7465`.
    /// Empty items (trailing commas, doubled separators) are dropped;
    /// `None` when the key is absent.
    pub fn get_list(&self, key: &str) -> Option<Vec<String>> {
        self.get(key).map(|v| {
            v.split(',').map(str::trim).filter(|s| !s.is_empty()).map(String::from).collect()
        })
    }

    pub fn get_bool(&self, key: &str, default: bool) -> Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some("true" | "1" | "yes") => Ok(true),
            Some("false" | "0" | "no") => Ok(false),
            Some(v) => Err(Error::Config(format!("{key}: bad bool '{v}'"))),
        }
    }

    /// Materialize a canonical job request from this config: keys present
    /// in the file become explicit fields, absent keys stay at request
    /// defaults. This is the config adapter onto the single
    /// `JobRequest::validate` path.
    pub fn job_request(&self) -> Result<JobRequest> {
        let mut req = JobRequest::default();
        if let Some(v) = self.get("variant") {
            req.variant = v.to_string();
        }
        if let Some(s) = self.get("precision") {
            req.precision = Precision::parse(s)?;
        }
        if let Some(s) = self.get("algorithm") {
            req.algorithm = crate::registration::algorithm::AlgorithmKind::parse(s)?;
        }
        if self.get("beta").is_some() {
            req.beta = Some(self.get_f64("beta", 0.0)?);
        }
        if self.get("gamma").is_some() {
            req.gamma = Some(self.get_f64("gamma", 0.0)?);
        }
        if self.get("gtol").is_some() {
            req.gtol = Some(self.get_f64("gtol", 0.0)?);
        }
        if self.get("max_iter").is_some() {
            req.max_iter = Some(self.get_usize("max_iter", 0)?);
        }
        if self.get("max_krylov").is_some() {
            req.max_krylov = Some(self.get_usize("max_krylov", 0)?);
        }
        if self.get("continuation").is_some() {
            req.continuation = Some(self.get_bool("continuation", true)?);
        }
        if self.get("multires").is_some() {
            req.multires = Some(self.get_usize("multires", 1)?);
        }
        if self.get("incompressible").is_some() {
            req.incompressible = Some(self.get_bool("incompressible", false)?);
        }
        if self.get("verbose").is_some() {
            req.verbose = Some(self.get_bool("verbose", false)?);
        }
        Ok(req)
    }

    /// Materialize solver parameters from this config — a thin adapter
    /// over [`JobRequest::validate`], the one validation path shared with
    /// the wire protocol and the CLI.
    pub fn reg_params(&self) -> Result<RegParams> {
        self.job_request()?.validate()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basic() {
        let c = Config::parse("a = 1\n# comment\nb = \"hello\"  # trailing\n\nbeta = 5e-4\n")
            .unwrap();
        assert_eq!(c.get("a"), Some("1"));
        assert_eq!(c.get("b"), Some("hello"));
        assert_eq!(c.get_f64("beta", 0.0).unwrap(), 5e-4);
    }

    #[test]
    fn bad_line_rejected() {
        assert!(Config::parse("just a line\n").is_err());
    }

    #[test]
    fn reg_params_defaults_and_overrides() {
        let c = Config::parse("variant = opt-fd8-linear\nmax_iter = 7\ncontinuation = false\n")
            .unwrap();
        let p = c.reg_params().unwrap();
        assert_eq!(p.variant, "opt-fd8-linear");
        assert_eq!(p.max_iter, 7);
        assert!(!p.continuation);
        assert_eq!(p.beta, 5e-4); // default preserved
        assert_eq!(p.precision, Precision::Full); // default policy
    }

    #[test]
    fn multires_key_parses() {
        let c = Config::parse("multires = 3\n").unwrap();
        assert_eq!(c.reg_params().unwrap().multires, 3);
        let d = Config::parse("beta = 5e-4\n").unwrap();
        assert_eq!(d.reg_params().unwrap().multires, 1, "absent = single grid");
    }

    #[test]
    fn precision_key_parses_and_rejects_unknown() {
        let c = Config::parse("precision = mixed\n").unwrap();
        assert_eq!(c.reg_params().unwrap().precision, Precision::Mixed);
        let bad = Config::parse("precision = fp8\n").unwrap();
        assert!(bad.reg_params().is_err());
    }

    #[test]
    fn algorithm_key_parses_and_rejects_unknown() {
        use crate::registration::algorithm::AlgorithmKind;
        let c = Config::parse("algorithm = gd\n").unwrap();
        assert_eq!(c.reg_params().unwrap().algorithm, AlgorithmKind::GradientDescent);
        let d = Config::parse("beta = 5e-4\n").unwrap();
        assert_eq!(d.reg_params().unwrap().algorithm, AlgorithmKind::GaussNewton);
        assert!(Config::parse("algorithm = newton\n").unwrap().reg_params().is_err());
    }

    #[test]
    fn list_parsing() {
        let c = Config::parse("backends = 127.0.0.1:7464, 127.0.0.1:7465,\n").unwrap();
        assert_eq!(
            c.get_list("backends").unwrap(),
            vec!["127.0.0.1:7464".to_string(), "127.0.0.1:7465".to_string()]
        );
        assert!(c.get_list("missing").is_none());
    }

    #[test]
    fn bool_parsing() {
        let c = Config::parse("x = yes\ny = 0\n").unwrap();
        assert!(c.get_bool("x", false).unwrap());
        assert!(!c.get_bool("y", true).unwrap());
        assert!(c.get_bool("missing", true).unwrap());
    }
}
