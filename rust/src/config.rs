//! Run configuration: `key = value` files plus CLI overrides.
//!
//! A deliberate TOML subset (serde/toml are unavailable offline): comments
//! with `#`, flat `key = value` pairs, strings unquoted or quoted. This is
//! the launcher's config surface — the analog of CLAIRE's PETSc options
//! files.

use std::collections::BTreeMap;
use std::path::Path;

use crate::error::{Error, Result};
use crate::precision::Precision;
use crate::registration::problem::RegParams;

/// Flat configuration map with typed accessors.
#[derive(Clone, Debug, Default)]
pub struct Config {
    values: BTreeMap<String, String>,
}

impl Config {
    pub fn parse(text: &str) -> Result<Config> {
        let mut values = BTreeMap::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| {
                Error::Config(format!("line {}: expected key = value, got '{raw}'", lineno + 1))
            })?;
            let v = v.trim().trim_matches('"').trim_matches('\'');
            values.insert(k.trim().to_string(), v.to_string());
        }
        Ok(Config { values })
    }

    pub fn load(path: &Path) -> Result<Config> {
        Self::parse(&std::fs::read_to_string(path)?)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(String::as_str)
    }

    pub fn set(&mut self, key: &str, value: &str) {
        self.values.insert(key.to_string(), value.to_string());
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => {
                v.parse().map_err(|_| Error::Config(format!("{key}: bad number '{v}'")))
            }
        }
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => {
                v.parse().map_err(|_| Error::Config(format!("{key}: bad integer '{v}'")))
            }
        }
    }

    pub fn get_bool(&self, key: &str, default: bool) -> Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some("true" | "1" | "yes") => Ok(true),
            Some("false" | "0" | "no") => Ok(false),
            Some(v) => Err(Error::Config(format!("{key}: bad bool '{v}'"))),
        }
    }

    /// Materialize solver parameters from this config.
    pub fn reg_params(&self) -> Result<RegParams> {
        let d = RegParams::default();
        Ok(RegParams {
            variant: self.get("variant").unwrap_or(&d.variant).to_string(),
            precision: match self.get("precision") {
                None => d.precision,
                Some(s) => Precision::parse(s)?,
            },
            beta: self.get_f64("beta", d.beta)?,
            gamma: self.get_f64("gamma", d.gamma)?,
            gtol: self.get_f64("gtol", d.gtol)?,
            max_iter: self.get_usize("max_iter", d.max_iter)?,
            max_krylov: self.get_usize("max_krylov", d.max_krylov)?,
            continuation: self.get_bool("continuation", d.continuation)?,
            multires: self.get_usize("multires", d.multires)?,
            incompressible: self.get_bool("incompressible", d.incompressible)?,
            verbose: self.get_bool("verbose", d.verbose)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basic() {
        let c = Config::parse("a = 1\n# comment\nb = \"hello\"  # trailing\n\nbeta = 5e-4\n")
            .unwrap();
        assert_eq!(c.get("a"), Some("1"));
        assert_eq!(c.get("b"), Some("hello"));
        assert_eq!(c.get_f64("beta", 0.0).unwrap(), 5e-4);
    }

    #[test]
    fn bad_line_rejected() {
        assert!(Config::parse("just a line\n").is_err());
    }

    #[test]
    fn reg_params_defaults_and_overrides() {
        let c = Config::parse("variant = opt-fd8-linear\nmax_iter = 7\ncontinuation = false\n")
            .unwrap();
        let p = c.reg_params().unwrap();
        assert_eq!(p.variant, "opt-fd8-linear");
        assert_eq!(p.max_iter, 7);
        assert!(!p.continuation);
        assert_eq!(p.beta, 5e-4); // default preserved
        assert_eq!(p.precision, Precision::Full); // default policy
    }

    #[test]
    fn multires_key_parses() {
        let c = Config::parse("multires = 3\n").unwrap();
        assert_eq!(c.reg_params().unwrap().multires, 3);
        let d = Config::parse("beta = 5e-4\n").unwrap();
        assert_eq!(d.reg_params().unwrap().multires, 1, "absent = single grid");
    }

    #[test]
    fn precision_key_parses_and_rejects_unknown() {
        let c = Config::parse("precision = mixed\n").unwrap();
        assert_eq!(c.reg_params().unwrap().precision, Precision::Mixed);
        let bad = Config::parse("precision = fp8\n").unwrap();
        assert!(bad.reg_params().is_err());
    }

    #[test]
    fn bool_parsing() {
        let c = Config::parse("x = yes\ny = 0\n").unwrap();
        assert!(c.get_bool("x", false).unwrap());
        assert!(!c.get_bool("y", true).unwrap());
        assert!(c.get_bool("missing", true).unwrap());
    }
}
