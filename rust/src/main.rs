//! `claire` — CLI launcher for the registration coordinator.
//!
//! Subcommands:
//!   register   run one registration (synthetic NIREP-analog pair)
//!   batch      run the clinical-style batch service over many jobs
//!   serve      start the persistent registration daemon (NDJSON over TCP)
//!   route      start the fleet router in front of N serve daemons
//!              (consistent-hash volume placement, affinity job routing,
//!              federated stats/status/watch)
//!   upload     ship a fixed/moving volume pair into a running daemon
//!   submit     submit job(s) to a running daemon (synthetic or uploaded)
//!   template   group-wise atlas building: iteratively register N subjects
//!              to a running template estimate and average server-side
//!              (wire `reduce` verb), with a journaled, restartable round
//!              loop and warm-started rounds
//!   watch      stream live job events from a running daemon (protocol v2)
//!   status     job table + stats from a running daemon
//!   cancel     cancel a queued or running job (running solves stop at
//!              the next solver iteration boundary)
//!   shutdown   stop a running daemon (drain by default)
//!   transport  warp the atlas with a random velocity (data utility)
//!   info       artifact inventory and platform info
//!   complexity Table-1 style kernel counts per operator
//!
//! The job-parameter surface (flags, config files, the wire protocol) is
//! one canonical type: `claire::JobRequest` — every subcommand builds one
//! via `JobRequest::from_args` and validates through the single
//! `JobRequest::validate()` path.
//!
//! Exit codes follow sysexits.h so scripts can branch without parsing
//! stderr: 75 = retryable daemon rejection (queue full / shutting down),
//! 64 = malformed request or usage, 65 = data-shape problem, 66 = unknown
//! job/volume id, 69 = daemon unreachable or transport failure, 70 =
//! internal daemon failure, 1 = any other local error.

use std::path::{Path, PathBuf};

use claire::coordinator::{BatchService, Job};
use claire::data::synth;
use claire::error::Result;
use claire::registration::{GaussNewtonKrylov, RunReport, Session};
use claire::runtime::OpRegistry;
use claire::serve::client::job_table;
use claire::serve::{
    pjrt_factory, Client, Daemon, DaemonConfig, EventMsg, JobSource, JobSpec, RetryPolicy,
    Router, RouterConfig, Verdict,
};
use claire::util::args::{flag, opt, usage, Args, OptSpec};
use claire::util::bench::Table;
use claire::JobRequest;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            e.exit_code()
        }
    };
    std::process::exit(code);
}

fn common_specs() -> Vec<OptSpec> {
    vec![
        opt("artifacts", "artifacts directory", "artifacts"),
        opt("n", "grid size (16|32|64)", "16"),
        opt("variant", "kernel variant tag", "opt-fd8-cubic"),
        opt("precision", "solver precision policy: full | mixed", "full"),
        opt("subject", "synthetic subject (na02|na03|na10)", "na02"),
        opt("beta", "target regularization weight", "5e-4"),
        opt("gamma", "divergence penalty", "1e-4"),
        opt("gtol", "relative gradient tolerance", "5e-2"),
        opt("max-iter", "max Gauss-Newton iterations", "50"),
        opt("workers", "batch worker threads", "2"),
        opt("algorithm", "solve algorithm: gn | gd | lbfgs", "gn"),
        opt("optimizer", "legacy alias for --algorithm", "gn"),
        opt("max-fo-iter", "iteration cap for gd/lbfgs (when --max-iter unset)", "100"),
        opt("dump-volumes", "directory to write before/after volumes", ""),
        opt("config", "key=value config file (overridden by flags)", ""),
        opt("multires", "grid-continuation levels (1 = single grid)", "1"),
        opt("addr", "daemon address (serve/upload/submit/status/shutdown)", "127.0.0.1:7464"),
        opt(
            "timeout-s",
            "daemon-client I/O timeout in seconds (0 = block forever); watch clears it \
             once subscribed",
            "30",
        ),
        opt("backend", "client: send via this address instead of --addr (router alias)", ""),
        opt("queue-cap", "serve: max waiting batch/urgent jobs", "64"),
        opt("coalesce-b", "serve: max jobs coalesced into one batched solve (1 disables)", "8"),
        opt("coalesce-ms", "serve: dwell for compatible peers before dispatch (ms)", "2"),
        opt("dedup", "submit: exactly-once token (resubmits return the original id)", ""),
        opt("journal", "serve: job journal path ('' disables)", "serve_journal.ndjson"),
        opt("store-mb", "serve: volume store byte budget (MiB)", "1024"),
        opt("node-id", "serve/route: stable node identity reported to fleet probes", ""),
        opt("backends", "route: comma-separated backend daemon addresses", ""),
        opt("replication", "route: holders per uploaded volume (0 = all nodes)", "1"),
        opt("probe-ms", "route: backend health-probe period (milliseconds)", "500"),
        opt(
            "route-journal",
            "route: routing-table journal path ('' disables)",
            "route_journal.ndjson",
        ),
        opt("fixed", "upload: fixed/reference volume (data/io .f32+.json path)", ""),
        opt("moving", "upload: moving/template volume (data/io .f32+.json path)", ""),
        opt("m0", "submit: content id of the uploaded moving/template volume", ""),
        opt("m1", "submit: content id of the uploaded fixed/reference volume", ""),
        opt("priority", "submit: batch | urgent | emergency", "batch"),
        opt("count", "submit: number of jobs (subjects cycle)", "1"),
        opt("id", "status/cancel: job id", ""),
        opt(
            "subjects",
            "template: comma-separated subject volumes (data/io paths or uploaded \
             content ids)",
            "",
        ),
        opt("rounds", "template: total round budget", "5"),
        opt("tol", "template: convergence tolerance on the template's relative change", "1e-3"),
        opt("step-scale", "template: scale on the mean velocity before exponentiation", "1"),
        opt(
            "state",
            "template: round-state journal for kill/restart resume ('' disables)",
            "template_state.ndjson",
        ),
        flag("quiet-events", "template: suppress the live per-job event stream"),
        flag("now", "shutdown: stop without draining queued jobs"),
        flag("no-continuation", "disable beta continuation"),
        flag("incompressible", "project onto divergence-free fields (Leray)"),
        flag("verbose", "per-iteration progress"),
    ]
}

fn open_registry(args: &Args) -> Result<OpRegistry> {
    let dir = PathBuf::from(args.get_or("artifacts", "artifacts"));
    OpRegistry::open(&dir)
}

/// Connect to the daemon with the `--timeout-s` bound (0 disables) and
/// negotiate protocol v2 when the daemon offers it (silently staying on
/// v1 against an old daemon).
fn connect_client(args: &Args) -> Result<Client> {
    // --backend (when set) wins over --addr: "this subcommand, via that
    // router/daemon" without disturbing a script's default --addr.
    let addr = match args.get("backend").filter(|s| !s.is_empty()) {
        Some(b) => b.to_string(),
        None => args.get_or("addr", "127.0.0.1:7464"),
    };
    let timeout_s = args.get_f64("timeout-s", 30.0)?;
    let mut client = if timeout_s > 0.0 {
        Client::connect_with_timeout(&addr, std::time::Duration::from_secs_f64(timeout_s))?
    } else {
        Client::connect(&addr)?
    };
    client.negotiate()?;
    Ok(client)
}

fn run(argv: Vec<String>) -> Result<()> {
    let Some(cmd) = argv.first().cloned() else {
        print_help();
        return Ok(());
    };
    let specs = common_specs();
    let args = Args::parse(argv[1..].to_vec(), &specs)?;
    match cmd.as_str() {
        "register" => cmd_register(&args),
        "batch" => cmd_batch(&args),
        "serve" => cmd_serve(&args),
        "route" => cmd_route(&args),
        "upload" => cmd_upload(&args),
        "submit" => cmd_submit(&args),
        "template" => cmd_template(&args),
        "watch" => cmd_watch(&args),
        "status" => cmd_status(&args),
        "cancel" => cmd_cancel(&args),
        "shutdown" => cmd_shutdown(&args),
        "transport" => cmd_transport(&args),
        "info" => cmd_info(&args),
        "complexity" => cmd_complexity(&args),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => {
            print_help();
            Err(claire::Error::Config(format!("unknown command '{other}'")))
        }
    }
}

fn print_help() {
    println!("claire — diffeomorphic image registration (JPDC 2020 reproduction)\n");
    println!("usage: claire <register|batch|serve|route|upload|submit|template|watch|status|");
    println!("               cancel|shutdown|transport|info|complexity> [options]\n");
    println!("{}", usage(&common_specs()));
    println!("exit codes (sysexits-style, for scripts): 75 retryable daemon rejection,");
    println!("  64 malformed request/usage, 65 shape problem, 66 unknown job/volume,");
    println!("  69 daemon unreachable/transport, 70 internal daemon failure");
}

fn cmd_register(args: &Args) -> Result<()> {
    let reg = open_registry(args)?;
    // `--optimizer`, the legacy spelling of `--algorithm`, is honored by
    // the shared `JobRequest::from_args` path (so submit/batch accept it
    // identically).
    let req = JobRequest::from_args(args)?;
    // First-order budgets resolve in the shared path: explicit
    // --max-iter/--max-fo-iter/config win, otherwise validate() applies
    // FIRST_ORDER_DEFAULT_MAX_ITER — identically on every surface.
    let params = req.validate()?;
    let (n, subject) = (req.n, req.subject.clone());
    println!("[claire] generating synthetic pair {subject}->na01 at {n}^3 ...");
    let prob = synth::nirep_analog_pair(&reg, n, &subject)?;
    let solver = GaussNewtonKrylov::new(&reg, params.clone());
    // Multires-aware warm-up: every planned coarse level compiles here,
    // not inside the timed solve (per-level breakdown printed).
    let plan = solver.precompile_plan(n)?;
    let total: f64 = plan.iter().map(|l| l.seconds).sum();
    let detail = plan
        .iter()
        .map(|l| format!("{}^3 {:.1}s", l.n, l.seconds))
        .collect::<Vec<_>>()
        .join(", ");
    println!("[claire] operators compiled in {total:.1}s ({detail}; one-time per process)");

    // One entry point for every algorithm: GN-Krylov (with multires /
    // continuation from the params) and the first-order baselines all run
    // through the Session and report the same way.
    let res = Session::new(&reg).params(params).solve(&prob)?;
    let report = RunReport::build(&solver, &prob, &res)?;
    let mut t = Table::new(&RunReport::headers());
    t.row(&report.row());
    t.print();
    if !res.converged {
        println!("(not converged to gtol within iteration budget)");
    }
    dump_volumes(args, &reg, &solver, &prob, &res)?;
    Ok(())
}

fn dump_volumes(
    args: &Args,
    _reg: &OpRegistry,
    solver: &GaussNewtonKrylov,
    prob: &claire::registration::RegProblem,
    res: &claire::registration::RegResult,
) -> Result<()> {
    let dir = args.get_or("dump-volumes", "");
    if dir.is_empty() {
        return Ok(());
    }
    let dir = PathBuf::from(dir);
    std::fs::create_dir_all(&dir)?;
    let n = prob.n();
    use claire::data::io::write_field;
    use claire::field::Field3;
    write_field(&dir.join("m0"), &prob.m0, "template image")?;
    write_field(&dir.join("m1"), &prob.m1, "reference image")?;
    let warped = solver.transport(&res.v, &prob.m0.data)?;
    let mism_before: Vec<f32> =
        prob.m0.data.iter().zip(&prob.m1.data).map(|(a, b)| (a - b).abs()).collect();
    let mism_after: Vec<f32> =
        warped.iter().zip(&prob.m1.data).map(|(a, b)| (a - b).abs()).collect();
    write_field(&dir.join("m0_warped"), &Field3::from_vec(n, warped)?, "deformed template")?;
    write_field(&dir.join("mismatch_before"), &Field3::from_vec(n, mism_before)?, "|m0-m1|")?;
    write_field(&dir.join("mismatch_after"), &Field3::from_vec(n, mism_after)?, "|m(1)-m1|")?;
    let detf = solver.detf(&res.v)?;
    write_field(&dir.join("detf"), &Field3::from_vec(n, detf)?, "det of deformation gradient")?;
    println!("[claire] volumes written to {}", dir.display());
    Ok(())
}

fn cmd_batch(args: &Args) -> Result<()> {
    let reg = open_registry(args)?;
    let req = JobRequest::from_args(args)?;
    let params = req.validate()?;
    let n = req.n;
    let workers = args.get_usize("workers", 2)?;
    let mut jobs = Vec::new();
    for (i, subject) in ["na02", "na03", "na10"].iter().enumerate() {
        jobs.push(Job {
            id: i,
            problem: synth::nirep_analog_pair(&reg, n, subject)?,
            params: params.clone(),
        });
    }
    println!("[claire] batch: {} jobs on {workers} workers ...", jobs.len());
    drop(reg); // workers open their own registries
    let svc = BatchService::new(PathBuf::from(args.get_or("artifacts", "artifacts")), workers);
    let rep = svc.run(jobs)?;
    let mut t = Table::new(&RunReport::headers());
    for o in &rep.outcomes {
        if let Some(r) = &o.report {
            t.row(&r.row());
        } else {
            println!("job {} FAILED: {}", o.id, o.error.as_deref().unwrap_or("?"));
        }
    }
    t.print();
    println!(
        "batch: {}/{} ok, wall {:.2}s, serial-equivalent {:.2}s, {:.3} reg/s",
        rep.succeeded(),
        rep.outcomes.len(),
        rep.wall_s,
        rep.serial_time(),
        rep.throughput()
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let journal = args.get_or("journal", "serve_journal.ndjson");
    let node_id = args.get_or("node-id", "");
    let cfg = DaemonConfig {
        addr: args.get_or("addr", "127.0.0.1:7464"),
        workers: args.get_usize("workers", 2)?,
        queue_cap: args.get_usize("queue-cap", 64)?,
        journal: (!journal.is_empty()).then(|| PathBuf::from(journal)),
        store_bytes: args.get_usize("store-mb", 1024)? as u64 * 1024 * 1024,
        node_id: (!node_id.is_empty()).then_some(node_id),
        coalesce_b: args.get_usize("coalesce-b", 8)?.max(1),
        coalesce_ms: args.get_usize("coalesce-ms", 2)? as u64,
    };
    let artifacts = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let handle = Daemon::start(cfg.clone(), pjrt_factory(artifacts))?;
    println!(
        "[claire] daemon listening on {} ({} workers, queue cap {}, journal {})",
        handle.addr(),
        cfg.workers,
        cfg.queue_cap,
        cfg.journal.as_ref().map(|p| p.display().to_string()).unwrap_or_else(|| "off".into())
    );
    let prior = handle.scheduler().stats().prior_completed;
    if prior > 0 {
        println!("[claire] journal reports {prior} jobs completed by previous runs");
    }
    println!("[claire] stop with: claire shutdown --addr {}", handle.addr());
    handle.join()
}

/// Start the fleet router in front of N serve daemons. Clients point any
/// existing subcommand at it (`--addr` or `--backend`) and get placement,
/// affinity routing, failover and federated stats/status/watch.
fn cmd_route(args: &Args) -> Result<()> {
    // Backends come from --backends, falling back to a config file's
    // `backends = host:port,host:port` key.
    let mut backends: Vec<String> = args
        .get_or("backends", "")
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(String::from)
        .collect();
    if backends.is_empty() {
        let cfg_path = args.get_or("config", "");
        if !cfg_path.is_empty() {
            if let Some(list) = claire::config::Config::load(Path::new(&cfg_path))?
                .get_list("backends")
            {
                backends = list;
            }
        }
    }
    if backends.is_empty() {
        return Err(claire::Error::Config(
            "route requires --backends host:port[,host:port...] (or a config file with a \
             'backends' key)"
            .into(),
        ));
    }
    let journal = args.get_or("route-journal", "route_journal.ndjson");
    let node_id = args.get_or("node-id", "");
    let timeout_s = args.get_f64("timeout-s", 30.0)?;
    let cfg = RouterConfig {
        addr: args.get_or("addr", "127.0.0.1:7470"),
        backends,
        replication: args.get_usize("replication", 1)?,
        probe_interval: std::time::Duration::from_millis(
            args.get_usize("probe-ms", 500)?.max(10) as u64,
        ),
        timeout: std::time::Duration::from_secs_f64(timeout_s.max(0.1)),
        journal: (!journal.is_empty()).then(|| PathBuf::from(journal)),
        node_id: (!node_id.is_empty()).then_some(node_id),
        retry: RetryPolicy::default(),
    };
    let n_backends = cfg.backends.len();
    let replication = cfg.replication;
    let handle = Router::start(cfg)?;
    println!(
        "[claire] router {} listening on {} ({} backends, replication {})",
        handle.node_id(),
        handle.addr(),
        n_backends,
        if replication == 0 { "all".to_string() } else { replication.to_string() }
    );
    println!("[claire] stop with: claire shutdown --addr {} (drains the fleet)", handle.addr());
    handle.join()
}

/// Ship a fixed/moving pair (data/io volume files) into a running daemon's
/// content-addressed store and print the ids a `submit` references.
fn cmd_upload(args: &Args) -> Result<()> {
    let addr = args.get_or("addr", "127.0.0.1:7464");
    let (fixed, moving) = (args.get_or("fixed", ""), args.get_or("moving", ""));
    if fixed.is_empty() || moving.is_empty() {
        return Err(claire::Error::Config(
            "upload requires --fixed <path> and --moving <path> (data/io volumes)".into(),
        ));
    }
    let m0 = claire::data::io::read_field(Path::new(&moving))?;
    let m1 = claire::data::io::read_field(Path::new(&fixed))?;
    if m0.n != m1.n {
        return Err(claire::Error::Config(format!(
            "volume sizes differ: moving {}^3 vs fixed {}^3",
            m0.n, m1.n
        )));
    }
    let mut client = connect_client(args)?;
    // Jittered retry on retryable daemon rejections (shutting_down races,
    // router-side unavailability) — transport failures still fail fast.
    let policy = RetryPolicy::default();
    let r0 = client.upload_with_retry(m0.n, &m0.data, &policy)?;
    let r1 = client.upload_with_retry(m1.n, &m1.data, &policy)?;
    let tag = |d: bool| if d { " (dedup hit)" } else { "" };
    println!("uploaded moving  (m0): {} [{}^3]{}", r0.id, r0.n, tag(r0.dedup));
    println!("uploaded fixed   (m1): {} [{}^3]{}", r1.id, r1.n, tag(r1.dedup));
    println!(
        "submit with: claire submit --addr {addr} --m0 {} --m1 {} --n {} [--multires 3]",
        r0.id, r1.id, r0.n
    );
    Ok(())
}

fn cmd_submit(args: &Args) -> Result<()> {
    // Validate client-side through the same single path the daemon uses —
    // a malformed request exits 64 without a round trip.
    let base = JobRequest::from_args(args)?;
    base.validate()?;
    let mut client = connect_client(args)?;
    let count = args.get_usize("count", 1)?;
    // Cycle through the study subjects only when the user did not pin one
    // (uploaded-source jobs always resubmit the same pair).
    let cycle =
        count > 1 && args.get("subject").is_none() && base.source == JobSource::Synthetic;
    let subjects = ["na02", "na03", "na10"];
    let specs: Vec<JobSpec> = (0..count)
        .map(|i| {
            if cycle {
                JobSpec { subject: subjects[i % subjects.len()].into(), ..base.clone() }
            } else {
                base.clone()
            }
        })
        .collect();
    if client.proto() >= 2 && specs.len() > 1 {
        // v2: one line, many jobs — per-job admission verdicts instead of
        // one round trip per job. Chunked under the protocol's per-line
        // job cap so a --count above it still submits everything.
        let mut first_rejection: Option<claire::Error> = None;
        let mut rejected = 0usize;
        for chunk in specs.chunks(claire::serve::proto::MAX_BATCH_JOBS) {
            let verdicts = client.submit_batch(chunk)?;
            for (spec, verdict) in chunk.iter().zip(&verdicts) {
                match verdict {
                    Verdict::Admitted { id } => println!(
                        "submitted job {id}: {} [{}]",
                        spec.name(),
                        spec.priority.as_str()
                    ),
                    Verdict::Rejected { code, msg, .. } => {
                        rejected += 1;
                        eprintln!("rejected {}: {msg} [{}]", spec.name(), code.as_str());
                        if first_rejection.is_none() {
                            first_rejection = Some(claire::Error::wire(*code, msg.clone()));
                        }
                    }
                }
            }
        }
        if let Some(e) = first_rejection {
            eprintln!("submit_batch: {rejected}/{} jobs rejected", specs.len());
            return Err(e);
        }
    } else {
        // Queue-full rejections on the single-submit path back off and
        // retry (full jitter) before surfacing exit code 75.
        let policy = RetryPolicy::default();
        for spec in &specs {
            let name = spec.name();
            let id = client.submit_with_retry(spec, &policy)?;
            println!("submitted job {id}: {name} [{}]", spec.priority.as_str());
        }
    }
    Ok(())
}

/// Group-wise template building (`template/` subsystem): upload the
/// subjects when given as paths, then drive the journaled round loop —
/// batch-submit one registration per subject against the current
/// template, reduce the retained outputs server-side into the next
/// template (wire `reduce` verb), warm-starting round 2+ from the
/// previous round's velocities. `--state` makes the loop restartable: a
/// killed driver re-run with the same flags resumes at the last
/// completed round.
fn cmd_template(args: &Args) -> Result<()> {
    let raw = args.get_or("subjects", "");
    let entries: Vec<String> = raw
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(str::to_string)
        .collect();
    let state = {
        let s = args.get_or("state", "template_state.ndjson");
        (!s.is_empty()).then(|| PathBuf::from(s))
    };
    let mut client = connect_client(args)?;
    if client.proto() < 2 {
        return Err(claire::Error::Serve(
            "template building requires a protocol-v2 daemon (reduce/submit_batch)".into(),
        ));
    }
    let policy = RetryPolicy::default();
    // Entries that name readable files are uploaded; anything else is
    // taken as an already-uploaded content id.
    let mut subjects = Vec::with_capacity(entries.len());
    for e in &entries {
        if Path::new(e).exists() {
            let f = claire::data::io::read_field(Path::new(e))?;
            let r = client.upload_with_retry(f.n, &f.data, &policy)?;
            println!("uploaded subject {e} -> {} [{}^3]", r.id, r.n);
            subjects.push(r.id);
        } else {
            subjects.push(e.clone());
        }
    }
    let mut base = JobRequest::from_args(args)?;
    // The driver owns source/warm_start/dedup per subject and round.
    base.source = JobSource::Synthetic;
    base.dedup = None;
    let cfg = claire::template::TemplateConfig {
        rounds: args.get_usize("rounds", 5)?,
        tol: args.get_f64("tol", 1e-3)?,
        scale: args.get_f64("step-scale", 1.0)?,
        state,
        policy,
        spec: base,
        wait_timeout_s: 600.0,
    };
    // Live progress: a second watch connection streams per-job events
    // alongside the driver's per-round lines.
    if !args.flag("quiet-events") {
        if let Ok(mut w) = connect_client(args) {
            if w.proto() >= 2 && w.watch().is_ok() && w.set_io_timeout(None).is_ok() {
                claire::util::sync::thread::spawn(move || loop {
                    match w.next_event() {
                        Ok(EventMsg::Job { id, name, state, .. }) => {
                            println!("  job {id} {name} -> {}", state.as_str());
                        }
                        Ok(EventMsg::Progress { id, iter, grad_rel, .. }) => {
                            println!("  job {id} it={iter} |g|rel={grad_rel:.2e}");
                        }
                        Ok(EventMsg::Lagged { .. }) | Err(_) => break,
                    }
                });
            }
        }
    }
    let mut driver = claire::template::TemplateDriver::new(client, subjects, cfg)?;
    let prior = driver.state().rounds.len();
    if prior > 0 {
        println!(
            "resuming run {} at round {} (template {})",
            driver.state().run_id,
            prior + 1,
            driver.template()
        );
    } else {
        println!(
            "bootstrap template {} ({} subjects, run {})",
            driver.template(),
            driver.state().subjects.len(),
            driver.state().run_id
        );
    }
    let outcomes = driver.run(|o| {
        let delta =
            o.delta_rel.map(|d| format!("{d:.3e}")).unwrap_or_else(|| "-".into());
        let iters: Vec<String> = o
            .iters
            .iter()
            .map(|i| i.map(|v| v.to_string()).unwrap_or_else(|| "-".into()))
            .collect();
        println!(
            "round {}: template {} delta_rel={delta} field={} iters=[{}]",
            o.round,
            o.template,
            o.field.as_str(),
            iters.join(",")
        );
    })?;
    match outcomes.last() {
        Some(last) if last.converged => {
            println!("converged after {} round(s): template {}", last.round, last.template);
        }
        _ => println!(
            "round budget exhausted ({}): template {}",
            driver.state().rounds.len(),
            driver.template()
        ),
    }
    Ok(())
}

/// Stream live job events from the daemon (protocol v2 `watch`). With
/// `--id`, exits once that job reaches a terminal state; otherwise streams
/// until interrupted or the daemon goes away. `--timeout-s` bounds only
/// connect + negotiation: once subscribed the I/O timeout is cleared,
/// because a long solve legitimately produces no events for minutes.
fn cmd_watch(args: &Args) -> Result<()> {
    let mut client = connect_client(args)?;
    if client.proto() < 2 {
        return Err(claire::Error::Serve(
            "daemon does not speak protocol v2 (watch unsupported)".into(),
        ));
    }
    client.watch()?;
    client.set_io_timeout(None)?;
    let filter = arg_job_id(args)?;
    match filter {
        Some(id) => println!("[claire] watching job {id} (until terminal)"),
        None => println!("[claire] watching job events (Ctrl-C to stop)"),
    }
    // Subscribe-then-check: a job that went terminal before the watch was
    // registered emits no further events, so without this probe the
    // command would sit on a finished job until the read timeout.
    if let Some(id) = filter {
        let view = client.status(id)?;
        if view.state.is_terminal() {
            println!("job {id} {} -> {} (already terminal)", view.name, view.state.as_str());
            return Ok(());
        }
    }
    loop {
        match client.next_event()? {
            EventMsg::Lagged { .. } => {
                // Exit non-zero: the watched outcome is unknown, and a
                // script chaining on success must not proceed. 69/retryable
                // (client-side unavailable): re-issue watch + a status probe.
                return Err(claire::Error::wire(
                    claire::ErrorCode::Unavailable,
                    "watch stream lagged behind and was dropped; re-issue watch",
                ));
            }
            EventMsg::Progress { id, name, iter, level, j, grad_rel, alpha, .. } => {
                // Live per-iteration line for running jobs (the tentpole's
                // acceptance surface: iter, J, ‖g‖rel, α).
                if filter.is_some_and(|want| want != id) {
                    continue;
                }
                println!(
                    "job {id} {name} it={iter} lvl={level} J={j:.4e} |g|rel={grad_rel:.2e} \
                     alpha={alpha:.2}"
                );
            }
            EventMsg::Job { id, name, state, wall_s, error, .. } => {
                // With --id, unrelated jobs' transitions are noise.
                if filter.is_some_and(|want| want != id) {
                    continue;
                }
                let detail = match (&error, wall_s) {
                    (Some(e), _) => format!("  ({e})"),
                    (None, Some(w)) => format!("  ({w:.2}s)"),
                    _ => String::new(),
                };
                println!("job {id} {name} -> {}{detail}", state.as_str());
                if filter == Some(id) && state.is_terminal() {
                    break;
                }
            }
        }
    }
    Ok(())
}

/// `--id` as a job id: `Ok(None)` when absent/empty, error on non-integer.
fn arg_job_id(args: &Args) -> Result<Option<u64>> {
    match args.get("id").filter(|s| !s.is_empty()) {
        None => Ok(None),
        Some(v) => v
            .parse()
            .map(Some)
            .map_err(|_| claire::Error::Config(format!("--id expects an integer, got '{v}'"))),
    }
}

fn cmd_status(args: &Args) -> Result<()> {
    let mut client = connect_client(args)?;
    match arg_job_id(args)? {
        Some(id) => {
            let v = client.status(id)?;
            job_table(std::slice::from_ref(&v)).print();
        }
        None => {
            let jobs = client.jobs()?;
            job_table(&jobs).print();
            let s = client.stats()?;
            println!(
                "stats: {} submitted, {} queued, {} running, {} done, {} failed, {} cancelled, \
                 {} rejected, {} prior; op cache: {} compiles, {} warm hits ({} workers)",
                s.submitted,
                s.queued,
                s.running,
                s.completed,
                s.failed,
                s.cancelled,
                s.rejected,
                s.prior_completed,
                s.cache_compiles,
                s.cache_hits,
                s.workers
            );
            println!(
                "store: {} volumes ({:.1} MiB), {} uploads, {} dedup hits, {} evictions",
                s.store.volumes,
                s.store.bytes as f64 / (1024.0 * 1024.0),
                s.store.uploads,
                s.store.dedup_hits,
                s.store.evictions
            );
            // Batch-occupancy counters appear once coalescing has fired;
            // a daemon that never batched keeps the pre-batching output.
            if s.batches > 0 || s.coalesced > 0 {
                let fill = if s.batches > 0 {
                    s.coalesced as f64 / s.batches as f64
                } else {
                    0.0
                };
                println!(
                    "batching: {} jobs coalesced into {} batches (mean fill {:.1})",
                    s.coalesced, s.batches, fill
                );
            }
            // Per-node breakdown arrives only from a router (fleet-merged
            // stats); single daemons report an empty list.
            if !s.nodes.is_empty() {
                let mut t = Table::new(&["node", "addr", "up", "queued", "running", "done", "routed"]);
                for nstat in &s.nodes {
                    t.row(&[
                        if nstat.node.is_empty() { "?".into() } else { nstat.node.clone() },
                        nstat.addr.clone(),
                        if nstat.up { "yes".into() } else { "NO".into() },
                        nstat.queued.to_string(),
                        nstat.running.to_string(),
                        nstat.completed.to_string(),
                        nstat.routed.to_string(),
                    ]);
                }
                t.print();
            }
        }
    }
    Ok(())
}

fn cmd_cancel(args: &Args) -> Result<()> {
    let mut client = connect_client(args)?;
    let Some(id) = arg_job_id(args)? else {
        return Err(claire::Error::Config("cancel requires --id".into()));
    };
    client.cancel(id)?;
    println!("cancelled job {id}");
    Ok(())
}

fn cmd_shutdown(args: &Args) -> Result<()> {
    let mut client = connect_client(args)?;
    let drain = !args.flag("now");
    client.shutdown(drain)?;
    println!("shutdown requested ({})", if drain { "drain" } else { "immediate" });
    Ok(())
}

fn cmd_transport(args: &Args) -> Result<()> {
    let reg = open_registry(args)?;
    let n = args.get_usize("n", 16)?;
    let (atlas, _) = synth::brain_atlas(n);
    let v = synth::smooth_random_velocity(n, 42, 2, 0.5);
    let op = reg.get("transport", &args.get_or("variant", "opt-fd8-cubic"), n)?;
    let out = op.call(&[&v.data, &atlas.data])?.remove(0);
    let rel = claire::math::stats::rel_l2(&out, &atlas.data);
    println!("transported atlas at {n}^3: relative change {rel:.4}");
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let reg = open_registry(args)?;
    println!(
        "platform: {} ({} devices)",
        reg.client.platform_name(),
        reg.client.device_count()
    );
    println!("artifacts: {} entries, Nt = {}", reg.manifest.artifacts.len(), reg.manifest.nt);
    let mut t = Table::new(&["op", "sizes", "variants(16^3)"]);
    let mut ops: Vec<String> = reg.manifest.artifacts.values().map(|a| a.op.clone()).collect();
    ops.sort();
    ops.dedup();
    for op in ops {
        let sizes = reg
            .manifest
            .sizes_for(&op)
            .iter()
            .map(|s| s.to_string())
            .collect::<Vec<_>>()
            .join(",");
        let vars = reg.manifest.variants_for(&op, 16).join(",");
        t.row(&[op, sizes, vars]);
    }
    t.print();
    Ok(())
}

fn cmd_complexity(args: &Args) -> Result<()> {
    // Paper Table 1: kernel counts per operator evaluation (d = 3, Nt = 4).
    let reg = open_registry(args)?;
    let nt = reg.manifest.nt;
    let d = 3;
    let mut t = Table::new(&["function", "#1st-order (FFT or FD)", "#FFT (other)", "#IPs"]);
    let char_ips = 2 * d; // RK2 trace: 2 stages x d components
    t.row(&[
        "objective (state eq)".into(),
        "0".into(),
        format!("{}", 2 * d),
        format!("{}", char_ips + nt),
    ]);
    t.row(&[
        "gradient (newton_setup)".into(),
        format!("{}", 1 + d * (nt + 1)),
        format!("{}", 4 * d),
        format!("{}", 2 * char_ips + 3 * nt),
    ]);
    t.row(&[
        "Hessian matvec".into(),
        format!("{}", d * (nt + 1)),
        format!("{}", 2 * d),
        format!("{}", 4 * nt),
    ]);
    t.print();
    println!("(d = {d}, Nt = {nt}; compare paper Table 1)");
    Ok(())
}
