//! Scheduler throughput/latency bench: N concurrent synthetic 64^3 jobs
//! through the serve scheduler, sweeping worker counts.
//!
//! Reports jobs/sec and p50/p95 submit-to-done latency (the clinical
//! figure of merit from `coordinator::workload`), batched-vs-sequential
//! dispatch throughput under scheduler job coalescing (B in {1, 4, 8}),
//! watch-event delivery latency through the v2 event bus, upload-line
//! encode throughput (owned pre-v2 path vs the borrowed encoder), and
//! writes a `BENCH_service.json` summary. Uses stub executors with a
//! calibrated busy-wait service time so the bench measures *scheduling*
//! overhead and scaling, not PJRT solve time — it runs on machines
//! without artifacts (pass a real artifacts dir via CLAIRE_ARTIFACTS +
//! `claire batch` for end-to-end solve throughput).
//!
//! Run: `cargo bench --bench bench_service`. Set `CLAIRE_BENCH_SMOKE=1`
//! to shrink every sweep to a seconds-scale CI smoke run.

use std::time::{Duration, Instant};

use claire::error::Result;
use claire::math::stats::percentile_sorted;
use claire::serve::proto::upload_line;
use claire::serve::scheduler::stub_report;
use claire::serve::{
    worker_loop, BusMsg, Executor, JobPayload, JobSpec, Priority, Request, Scheduler,
    VolumeStore,
};
use claire::util::bench::Table;
use claire::util::json::Json;

fn spin(d: Duration) {
    let t0 = Instant::now();
    while t0.elapsed() < d {
        std::hint::spin_loop();
    }
}

/// Busy-wait executor: emulates a fixed per-job solve cost without
/// sleeping (sleep granularity would swamp sub-ms scheduling overhead).
struct SpinExec {
    service: Duration,
}

impl Executor for SpinExec {
    fn execute(
        &mut self,
        payload: &JobPayload,
        _cx: &claire::registration::SolveCx,
    ) -> Result<claire::serve::ExecOutcome> {
        spin(self.service);
        Ok(stub_report(&payload.name()).into())
    }
}

/// Busy-wait executor with the real batched-solve cost shape: every
/// dispatch pays a fixed `base` (operator marshalling, executable launch),
/// plus `per_subject` per member. Batching amortizes `base` across the
/// batch — exactly what one warm `__b{B}` executable does for B subjects.
struct BatchSpinExec {
    base: Duration,
    per_subject: Duration,
}

impl Executor for BatchSpinExec {
    fn execute(
        &mut self,
        payload: &JobPayload,
        _cx: &claire::registration::SolveCx,
    ) -> Result<claire::serve::ExecOutcome> {
        spin(self.base + self.per_subject);
        Ok(stub_report(&payload.name()).into())
    }

    fn execute_batch(
        &mut self,
        jobs: &[(JobPayload, claire::registration::SolveCx)],
    ) -> Vec<Result<claire::serve::ExecOutcome>> {
        spin(self.base + self.per_subject * jobs.len() as u32);
        jobs.iter().map(|(p, _)| Ok(stub_report(&p.name()).into())).collect()
    }
}

struct Row {
    workers: usize,
    wall_s: f64,
    jobs_per_s: f64,
    p50_s: f64,
    p95_s: f64,
}

fn run_once(jobs: usize, workers: usize, service: Duration) -> Row {
    let sched = Scheduler::new(jobs, workers);
    for i in 0..jobs {
        let spec = JobSpec {
            subject: ["na02", "na03", "na10"][i % 3].into(),
            n: 64,
            priority: Priority::Batch,
            ..Default::default()
        };
        sched.submit(Priority::Batch, JobPayload::Spec(spec)).unwrap();
    }
    sched.shutdown(true); // drain
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for w in 0..workers {
            let sched = sched.clone();
            scope.spawn(move || {
                let mut exec = SpinExec { service };
                worker_loop(&sched, w, &mut exec);
            });
        }
    });
    let wall_s = t0.elapsed().as_secs_f64();
    let mut lat: Vec<f64> = sched.jobs().iter().filter_map(|v| v.latency_s).collect();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Row {
        workers,
        wall_s,
        jobs_per_s: jobs as f64 / wall_s.max(1e-12),
        p50_s: percentile_sorted(&lat, 50.0),
        p95_s: percentile_sorted(&lat, 95.0),
    }
}

/// One coalesced-dispatch sweep point: `jobs` compatible batch-priority
/// jobs drained through a single worker with coalescing capped at
/// `max_b`. `max_b = 1` disables coalescing — the sequential baseline the
/// speedup column compares against. The queue is fully loaded before the
/// worker starts (drain mode skips the dwell), so fills are deterministic.
struct BatchRow {
    max_b: usize,
    wall_s: f64,
    jobs_per_s: f64,
    batches: u64,
    coalesced: u64,
    mean_fill: f64,
}

fn run_batched_once(jobs: usize, max_b: usize, base: Duration, per: Duration) -> BatchRow {
    let sched = Scheduler::new(jobs, 1);
    sched.set_coalesce(max_b, 0);
    for i in 0..jobs {
        let spec = JobSpec {
            subject: ["na02", "na03", "na10"][i % 3].into(),
            n: 64,
            priority: Priority::Batch,
            ..Default::default()
        };
        sched.submit(Priority::Batch, JobPayload::Spec(spec)).unwrap();
    }
    sched.shutdown(true); // drain
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        let sched = sched.clone();
        scope.spawn(move || {
            let mut exec = BatchSpinExec { base, per_subject: per };
            worker_loop(&sched, 0, &mut exec);
        });
    });
    let wall_s = t0.elapsed().as_secs_f64();
    let s = sched.stats();
    assert_eq!(s.completed as usize, jobs, "every job completes under coalescing");
    BatchRow {
        max_b,
        wall_s,
        jobs_per_s: jobs as f64 / wall_s.max(1e-12),
        batches: s.batches,
        coalesced: s.coalesced,
        mean_fill: if s.batches > 0 { s.coalesced as f64 / s.batches as f64 } else { 1.0 },
    }
}

/// Volume-store throughput: cold puts (hash + insert), dedup re-puts
/// (hash + LRU touch) and resolves, over 64^3 volumes (1 MiB each) — the
/// data plane's admission-path costs.
struct StoreRow {
    cold_puts_per_s: f64,
    cold_mb_per_s: f64,
    dedup_puts_per_s: f64,
    gets_per_s: f64,
}

fn run_store_bench(volumes: usize, n: usize) -> StoreRow {
    let bytes_per = n * n * n * 4;
    let store = VolumeStore::new((volumes * bytes_per) as u64);
    let make = |seed: usize| -> Vec<f32> {
        // Cheap deterministic content; distinct per seed so cold puts
        // never dedup.
        (0..n * n * n).map(|i| (seed * 31 + i) as f32).collect()
    };
    // Pre-build the volumes — and the owned copies `put` consumes — so the
    // measured loops are pure store cost (hash + insert / LRU touch), not
    // generation or memcpy cost.
    let cold_set: Vec<Vec<f32>> = (0..volumes).map(make).collect();
    let dedup_set = cold_set.clone();

    let t0 = Instant::now();
    let ids: Vec<String> =
        cold_set.into_iter().map(|v| store.put(n, v).unwrap().id).collect();
    let cold_s = t0.elapsed().as_secs_f64().max(1e-12);

    let t0 = Instant::now();
    for v in dedup_set {
        assert!(store.put(n, v).unwrap().dedup);
    }
    let dedup_s = t0.elapsed().as_secs_f64().max(1e-12);

    let t0 = Instant::now();
    for id in &ids {
        assert!(store.get(id).is_some());
    }
    let get_s = t0.elapsed().as_secs_f64().max(1e-12);

    let stats = store.stats();
    assert_eq!(stats.volumes, volumes);
    assert_eq!(stats.dedup_hits, volumes as u64);
    StoreRow {
        cold_puts_per_s: volumes as f64 / cold_s,
        cold_mb_per_s: (volumes * bytes_per) as f64 / (1024.0 * 1024.0) / cold_s,
        dedup_puts_per_s: volumes as f64 / dedup_s,
        gets_per_s: volumes as f64 / get_s,
    }
}

/// Watch-event delivery latency: a subscriber timestamps every bus event
/// while the producer drives `jobs` full lifecycles (queued -> running ->
/// done = 3 events each) through the scheduler, recording the emit time
/// before each transition call. Delivery latency = arrival - emit: the
/// bus queue + thread-wakeup cost a `watch` connection sees on top of the
/// transition itself.
struct WatchRow {
    events: usize,
    p50_us: f64,
    p95_us: f64,
    max_us: f64,
}

fn run_watch_bench(jobs: usize) -> WatchRow {
    let sched = Scheduler::new(jobs, 1);
    let handle = sched.watch();
    let total = jobs * 3;
    let (emits, arrivals) = std::thread::scope(|scope| {
        let sub = scope.spawn(|| {
            let mut arr = Vec::with_capacity(total);
            while arr.len() < total {
                match handle.recv() {
                    Some(BusMsg::Event(_)) => arr.push(Instant::now()),
                    Some(BusMsg::Lagged) => panic!("bench subscriber lagged"),
                    None => break,
                }
            }
            arr
        });
        let mut emits = Vec::with_capacity(total);
        for i in 0..jobs {
            let spec = JobSpec { subject: format!("w{i}"), ..Default::default() };
            emits.push(Instant::now());
            sched.submit(Priority::Batch, JobPayload::Spec(spec)).unwrap();
            emits.push(Instant::now());
            let (id, _) = sched.next_job(0).unwrap();
            emits.push(Instant::now());
            sched.complete(id, Ok(stub_report("w").into()), 0.0);
        }
        (emits, sub.join().unwrap())
    });
    sched.unwatch(handle.id());
    assert_eq!(arrivals.len(), total, "every transition delivered");
    let mut lat_us: Vec<f64> = emits
        .iter()
        .zip(&arrivals)
        .map(|(e, a)| a.saturating_duration_since(*e).as_secs_f64() * 1e6)
        .collect();
    lat_us.sort_by(|a, b| a.partial_cmp(b).unwrap());
    WatchRow {
        events: total,
        p50_us: percentile_sorted(&lat_us, 50.0),
        p95_us: percentile_sorted(&lat_us, 95.0),
        max_us: *lat_us.last().unwrap(),
    }
}

/// Upload-line encode throughput: the pre-v2 owned path (clone the volume
/// into `Request::Upload`, render through the Json tree) vs the borrowed
/// `upload_line` encoder (one transient byte copy, base64 appended in
/// place). The delta is the satellite's receipt for dropping the
/// client-side `to_vec`.
struct EncodeRow {
    owned_mb_per_s: f64,
    borrowed_mb_per_s: f64,
    speedup: f64,
}

fn run_upload_encode_bench(n: usize, iters: usize) -> EncodeRow {
    let data: Vec<f32> = (0..n * n * n).map(|i| (i as f32 * 0.1).sin()).collect();
    let mb = (n * n * n * 4) as f64 / (1024.0 * 1024.0);

    let t0 = Instant::now();
    for _ in 0..iters {
        let line = Request::Upload { n, data: data.clone() }.to_line();
        std::hint::black_box(&line);
    }
    let owned_s = t0.elapsed().as_secs_f64().max(1e-12);

    let t0 = Instant::now();
    for _ in 0..iters {
        let line = upload_line(n, &data, None);
        std::hint::black_box(&line);
    }
    let borrowed_s = t0.elapsed().as_secs_f64().max(1e-12);

    EncodeRow {
        owned_mb_per_s: iters as f64 * mb / owned_s,
        borrowed_mb_per_s: iters as f64 * mb / borrowed_s,
        speedup: owned_s / borrowed_s,
    }
}

fn main() {
    // Smoke mode (CLAIRE_BENCH_SMOKE=1): every sweep shrinks to a
    // seconds-scale run so CI can exercise the full bench path — including
    // the BENCH_service.json artifact — without bench-grade runtimes.
    let smoke = std::env::var("CLAIRE_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    if smoke {
        println!("[smoke mode: CLAIRE_BENCH_SMOKE=1 — reduced sweep sizes]\n");
    }
    let jobs = if smoke { 8usize } else { 48usize };
    let service = Duration::from_millis(if smoke { 1 } else { 4 });
    println!("== serve scheduler: {jobs} synthetic 64^3 jobs, {service:?} service time ==\n");

    let mut table = Table::new(&["workers", "wall[s]", "jobs/s", "p50 lat[s]", "p95 lat[s]"]);
    let mut rows = Vec::new();
    for workers in [1usize, 2, 4, 8] {
        // Warmup pass absorbs thread spawn + allocator effects.
        run_once(jobs / 4, workers, service);
        let row = run_once(jobs, workers, service);
        table.row(&[
            row.workers.to_string(),
            format!("{:.3}", row.wall_s),
            format!("{:.1}", row.jobs_per_s),
            format!("{:.4}", row.p50_s),
            format!("{:.4}", row.p95_s),
        ]);
        rows.push(row);
    }
    table.print();
    println!("\n(expected: jobs/s scales ~linearly in workers until core count;");
    println!(" p95 latency drops as queue wait shrinks — cf. workload.rs M/D/c model)");

    let batch_jobs = if smoke { 8usize } else { 32usize };
    let batch_base = Duration::from_millis(if smoke { 1 } else { 2 });
    let batch_per = Duration::from_millis(if smoke { 1 } else { 2 });
    println!(
        "\n== coalesced dispatch: {batch_jobs} compatible jobs, 1 worker, \
         cost = {batch_base:?} + B x {batch_per:?} ==\n"
    );
    let mut bt = Table::new(&[
        "max B", "wall[s]", "jobs/s", "batches", "coalesced", "mean fill", "speedup",
    ]);
    let mut batch_rows: Vec<BatchRow> = Vec::new();
    for max_b in [1usize, 4, 8] {
        run_batched_once(batch_jobs / 4, max_b, batch_base, batch_per); // warmup
        let row = run_batched_once(batch_jobs, max_b, batch_base, batch_per);
        batch_rows.push(row);
    }
    let seq_jps = batch_rows[0].jobs_per_s;
    for r in &batch_rows {
        bt.row(&[
            r.max_b.to_string(),
            format!("{:.3}", r.wall_s),
            format!("{:.1}", r.jobs_per_s),
            r.batches.to_string(),
            r.coalesced.to_string(),
            format!("{:.1}", r.mean_fill),
            format!("{:.2}x", r.jobs_per_s / seq_jps.max(1e-12)),
        ]);
    }
    bt.print();
    println!("\n(max B = 1 is the sequential baseline; coalescing amortizes the");
    println!(" per-dispatch base cost across the batch, the way one warm __bB");
    println!(" executable evaluates B subjects per operator call)");

    let store_vols = if smoke { 8usize } else { 32usize };
    let store_n = 64usize;
    println!("\n== volume store: {store_vols} x {store_n}^3 volumes (1 MiB each) ==\n");
    // Warmup pass absorbs allocator effects, as above.
    run_store_bench(store_vols / 4, store_n);
    let sr = run_store_bench(store_vols, store_n);
    let mut st = Table::new(&["cold puts/s", "cold MB/s", "dedup puts/s", "gets/s"]);
    st.row(&[
        format!("{:.0}", sr.cold_puts_per_s),
        format!("{:.0}", sr.cold_mb_per_s),
        format!("{:.0}", sr.dedup_puts_per_s),
        format!("{:.0}", sr.gets_per_s),
    ]);
    st.print();
    println!("\n(cold puts pay the FNV-1a content hash over the volume bytes;");
    println!(" dedup re-puts pay the same hash but skip the copy — upload");
    println!(" admission cost is hash-bound either way)");

    let watch_jobs = if smoke { 8usize } else { 64usize };
    println!("\n== watch event bus: {watch_jobs} job lifecycles, 1 subscriber ==\n");
    run_watch_bench(watch_jobs / 4); // warmup
    let wr = run_watch_bench(watch_jobs);
    let mut wt = Table::new(&["events", "p50 lat[us]", "p95 lat[us]", "max[us]"]);
    wt.row(&[
        wr.events.to_string(),
        format!("{:.1}", wr.p50_us),
        format!("{:.1}", wr.p95_us),
        format!("{:.1}", wr.max_us),
    ]);
    wt.print();
    println!("\n(delivery latency = bus queue + subscriber wakeup per transition;");
    println!(" the bounded queue means a wedged subscriber lags out instead of");
    println!(" adding backpressure here)");

    let enc_n = if smoke { 32usize } else { 64usize };
    let enc_iters = if smoke { 8usize } else { 32usize };
    println!("\n== upload-line encode: {enc_n}^3 volume (1 MiB), {enc_iters} iters ==\n");
    run_upload_encode_bench(enc_n, enc_iters / 4); // warmup
    let er = run_upload_encode_bench(enc_n, enc_iters);
    let mut et = Table::new(&["owned MB/s", "borrowed MB/s", "speedup"]);
    et.row(&[
        format!("{:.0}", er.owned_mb_per_s),
        format!("{:.0}", er.borrowed_mb_per_s),
        format!("{:.2}x", er.speedup),
    ]);
    et.print();
    println!("\n(owned = pre-v2 client path: clone volume -> Json tree -> escape");
    println!(" pass; borrowed = upload_line straight from the slice, base64");
    println!(" appended in place — one transient byte copy)");

    let summary = Json::object([
        ("bench", Json::str("service")),
        ("jobs", Json::num(jobs as f64)),
        ("n", Json::num(64.0)),
        ("service_ms", Json::num(service.as_secs_f64() * 1e3)),
        (
            "sweeps",
            Json::Arr(
                rows.iter()
                    .map(|r| {
                        Json::object([
                            ("workers", Json::num(r.workers as f64)),
                            ("wall_s", Json::num(r.wall_s)),
                            ("jobs_per_s", Json::num(r.jobs_per_s)),
                            ("p50_s", Json::num(r.p50_s)),
                            ("p95_s", Json::num(r.p95_s)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "batched",
            Json::object([
                ("jobs", Json::num(batch_jobs as f64)),
                ("base_ms", Json::num(batch_base.as_secs_f64() * 1e3)),
                ("per_subject_ms", Json::num(batch_per.as_secs_f64() * 1e3)),
                (
                    "sweeps",
                    Json::Arr(
                        batch_rows
                            .iter()
                            .map(|r| {
                                Json::object([
                                    ("max_b", Json::num(r.max_b as f64)),
                                    ("wall_s", Json::num(r.wall_s)),
                                    ("jobs_per_s", Json::num(r.jobs_per_s)),
                                    ("batches", Json::num(r.batches as f64)),
                                    ("coalesced", Json::num(r.coalesced as f64)),
                                    ("mean_fill", Json::num(r.mean_fill)),
                                    (
                                        "speedup_vs_sequential",
                                        Json::num(r.jobs_per_s / seq_jps.max(1e-12)),
                                    ),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]),
        ),
        (
            "store",
            Json::object([
                ("volumes", Json::num(store_vols as f64)),
                ("n", Json::num(store_n as f64)),
                ("cold_puts_per_s", Json::num(sr.cold_puts_per_s)),
                ("cold_mb_per_s", Json::num(sr.cold_mb_per_s)),
                ("dedup_puts_per_s", Json::num(sr.dedup_puts_per_s)),
                ("gets_per_s", Json::num(sr.gets_per_s)),
            ]),
        ),
        (
            "watch",
            Json::object([
                ("events", Json::num(wr.events as f64)),
                ("p50_us", Json::num(wr.p50_us)),
                ("p95_us", Json::num(wr.p95_us)),
                ("max_us", Json::num(wr.max_us)),
            ]),
        ),
        (
            "upload_encode",
            Json::object([
                ("n", Json::num(enc_n as f64)),
                ("owned_mb_per_s", Json::num(er.owned_mb_per_s)),
                ("borrowed_mb_per_s", Json::num(er.borrowed_mb_per_s)),
                ("speedup", Json::num(er.speedup)),
            ]),
        ),
    ]);
    let out = "BENCH_service.json";
    match std::fs::write(out, summary.render() + "\n") {
        Ok(()) => println!("\nsummary written to {out}"),
        Err(e) => eprintln!("\ncould not write {out}: {e}"),
    }
}
