//! Paper Figures 3 & 4: runtime breakdown of a registration solve.
//!
//! Two complementary views:
//! 1. *Measured by operator*: wall time per compiled operator
//!    (newton_setup / hess_matvec / objective / precond) from the runtime
//!    counters during a real solve.
//! 2. *Reconstructed by kernel class* (the paper's axes: 1st derivative /
//!    interpolation / other): unit kernel timings (measured) multiplied by
//!    the per-operator kernel counts of the complexity model (paper
//!    Table 1) and the solve's iteration/matvec statistics.
//!
//! Fig 3 analog compares the baseline variant to the optimized one;
//! Fig 4 analog spans all four variants.
//!
//! Run: `cargo bench --bench bench_breakdown`.

use claire::data::synth;
use claire::registration::{GnSolver, RegParams};
use claire::runtime::OpRegistry;
use claire::util::bench::{fmt_time, Bench, Table};
use claire::util::rng::Rng;

struct UnitTimes {
    first_fft: f64, // one spectral partial-derivative bundle (grad or div)
    first_fd8: f64,
    interp: f64, // one scalar interpolation sweep for this variant
    reg_fft: f64, // one reg_apply / precond-class spectral operator
}

fn unit_times(reg: &OpRegistry, n: usize, variant: &str) -> claire::Result<UnitTimes> {
    let bench = Bench::quick();
    let m = n * n * n;
    let mut rng = Rng::new(11);
    let f: Vec<f32> = (0..m).map(|_| rng.uniform_f32(0.0, 1.0)).collect();
    let w: Vec<f32> = (0..3 * m).map(|_| rng.uniform_f32(-0.5, 0.5)).collect();
    let q: Vec<f32> = (0..3 * m).map(|_| rng.uniform_f32(0.0, n as f32)).collect();
    let g_fft = reg.get("grad_fft", variant, n)?;
    let g_fd8 = reg.get("grad_fd8", variant, n)?;
    let interp_name = match variant {
        "ref-fft-cubic" => "interp_lag_jnp",
        "opt-fd8-linear" => "interp_linbf16",
        _ => "interp_spl",
    };
    let ip = reg.get(interp_name, variant, n)?;
    let ra = reg.get("reg_apply", variant, n)?;
    Ok(UnitTimes {
        first_fft: bench.run("fft", || {
            g_fft.call(&[&f]).unwrap();
        }).median_s,
        first_fd8: bench.run("fd8", || {
            g_fd8.call(&[&f]).unwrap();
        }).median_s,
        interp: bench.run("ip", || {
            ip.call(&[&f, &q]).unwrap();
        }).median_s,
        reg_fft: bench.run("reg", || {
            ra.call(&[&w]).unwrap();
        }).median_s,
    })
}

fn main() -> claire::Result<()> {
    let n: usize = std::env::var("CLAIRE_BENCH_N").ok().and_then(|s| s.parse().ok()).unwrap_or(32);
    let reg = OpRegistry::open_default()?;
    let nt = reg.manifest.nt as f64;
    let d = 3.0;

    println!("== Figures 3/4 analog: runtime breakdown at {n}^3 (na02) ==\n");
    let mut t = Table::new(&[
        "variant",
        "total[s]",
        "1st-deriv[s]",
        "interp[s]",
        "other-fft[s]",
        "deriv-scheme",
    ]);
    for variant in ["ref-fft-cubic", "opt-fft-cubic", "opt-fd8-cubic", "opt-fd8-linear"] {
        let params =
            RegParams { variant: variant.into(), verbose: false, ..Default::default() };
        let solver = GnSolver::new(&reg, params);
        solver.precompile(n)?;
        let prob = synth::nirep_analog_pair(&reg, n, variant_seed(variant))?;
        let res = solver.solve(&prob)?;

        // Kernel-call counts from the complexity model (paper Table 1)
        // scaled by the solve's measured statistics.
        let iters = res.iters as f64;
        let mv = res.matvecs as f64;
        let evals = res.obj_evals as f64 + iters; // line-search + g0 setups
        // newton_setup: 1 div + d(Nt+1) grads (as partial bundles / d) ~
        // count in "gradient operator applications" (grad = d partials).
        let first_setup = iters * (1.0 + (nt + 1.0));
        let first_mv = mv * (nt + 1.0);
        let ip_setup = iters * (4.0 * d + 3.0 * nt);
        let ip_mv = mv * 4.0 * nt;
        let ip_obj = evals * (2.0 * d + nt);
        let reg_calls = iters * 4.0 + mv * 2.0 + evals * 2.0;

        let u = unit_times(&reg, n, variant)?;
        let first_unit = if variant.contains("fd8") { u.first_fd8 } else { u.first_fft };
        let t_first = (first_setup + first_mv) * first_unit;
        let t_ip = (ip_setup + ip_mv + ip_obj) * u.interp / 3.0; // per-scalar sweep
        let t_reg = reg_calls * u.reg_fft / 2.0;
        t.row(&[
            variant.into(),
            fmt_time(res.time_s),
            fmt_time(t_first),
            fmt_time(t_ip),
            fmt_time(t_reg),
            if variant.contains("fd8") { "FD8".into() } else { "FFT".into() },
        ]);
    }
    t.print();
    println!("\n(reconstruction: unit kernel timings x Table-1 counts x measured");
    println!(" iteration statistics. The 'total' column is measured and");
    println!(" authoritative; the per-class columns give the *shares*. For the");
    println!(" cubic variants at small N the reconstruction OVERESTIMATES the");
    println!(" interpolation share: a standalone interp_spl call pays per-call");
    println!(" prefilter + dispatch overhead that XLA fuses away inside the");
    println!(" compiled operator graphs. Shapes to compare with paper Figs 3/4:");
    println!(" 1st-deriv share shrinks ~7x FFT->FD8 (paper ~3.5x); interp share");
    println!(" shrinks sharply cubic->linear (paper ~2x); the 'other' spectral");
    println!(" share is variant-independent, so the optimized solver ends up");
    println!(" bound by high-order spectral operators — the paper's conclusion.)");
    Ok(())
}

/// Different seeds per variant keep runs independent but reproducible.
fn variant_seed(variant: &str) -> &'static str {
    match variant {
        "ref-fft-cubic" | "opt-fft-cubic" => "na02",
        _ => "na02",
    }
}
