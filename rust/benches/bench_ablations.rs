//! Ablations of the solver's design choices (DESIGN.md section 6 extras).
//!
//! The paper fixes several design decisions without dedicated tables; this
//! bench quantifies them on our testbed:
//!   1. beta continuation on/off (paper section 4.1.2 / ref [51]),
//!   2. grid continuation (multi-resolution) off/2-level,
//!   3. H1-div penalty vs hard incompressibility (Leray projection),
//!   4. target regularization weight sweep (the paper's note that beta
//!      should track resolution).
//!
//! Run: `cargo bench --bench bench_ablations` (size via CLAIRE_BENCH_N).

use claire::data::synth;
use claire::registration::{GnSolver, RegParams, RunReport};
use claire::runtime::OpRegistry;
use claire::util::bench::Table;

fn main() -> claire::Result<()> {
    let n: usize = std::env::var("CLAIRE_BENCH_N").ok().and_then(|s| s.parse().ok()).unwrap_or(32);
    let reg = OpRegistry::open_default()?;
    let prob = synth::nirep_analog_pair(&reg, n, "na02")?;

    let mut t = Table::new(&[
        "ablation", "mism", "|g|rel", "detF.min", "detF.max", "#iter", "#MV", "time[s]",
    ]);
    let base = RegParams::default();

    let mut run = |label: &str, params: RegParams, multires: usize| -> claire::Result<()> {
        let solver = GnSolver::new(&reg, params);
        solver.precompile(n)?;
        let res = if multires > 1 {
            solver.solve_multires(&prob, multires)?
        } else {
            solver.solve(&prob)?
        };
        let report = RunReport::build(&solver, &prob, &res)?;
        t.row(&[
            label.into(),
            format!("{:.1e}", res.mismatch_rel),
            format!("{:.1e}", res.grad_rel),
            format!("{:.2}", report.detf.min),
            format!("{:.2}", report.detf.max),
            res.iters.to_string(),
            res.matvecs.to_string(),
            format!("{:.2}", res.time_s),
        ]);
        Ok(())
    };

    run("default (continuation, H1-div)", base.clone(), 1)?;
    run(
        "no beta continuation",
        RegParams { continuation: false, ..base.clone() },
        1,
    )?;
    run("grid continuation (2 levels)", base.clone(), 2)?;
    run(
        "incompressible (Leray)",
        RegParams { incompressible: true, ..base.clone() },
        1,
    )?;
    for beta in [5e-3, 5e-5] {
        run(
            &format!("beta target {beta:.0e}"),
            RegParams { beta, ..base.clone() },
            1,
        )?;
    }

    println!("== ablations at {n}^3 (na02) ==");
    t.print();
    println!("\n(expected: continuation costs extra coarse-beta iterations but");
    println!(" yields equal-or-better final mismatch with better-conditioned");
    println!(" det F; smaller beta -> lower mismatch but wilder det F; Leray");
    println!(" keeps det F tightest at some mismatch cost; grid continuation");
    println!(" trades fine-level matvecs for cheap coarse ones.)");
    Ok(())
}
