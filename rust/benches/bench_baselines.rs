//! Paper Table 8: the proposed Gauss-Newton-Krylov solver vs first-order
//! LDDMM baselines (PyCA ~ gradient descent, deformetrica ~ L-BFGS), run
//! over the *same* objective/gradient artifacts so only the optimizer
//! differs.
//!
//! The paper's argument reproduced here: first-order methods do cheap
//! iterations but need far more of them to reach a given mismatch; the
//! second-order solver reaches a ~10x better mismatch in less time.
//!
//! Run: `cargo bench --bench bench_baselines`.

use claire::data::synth;
use claire::registration::{run_baseline, BaselineKind, GnSolver, RegParams};
use claire::runtime::OpRegistry;
use claire::util::bench::{fmt_time, Table};

fn main() -> claire::Result<()> {
    let n: usize = std::env::var("CLAIRE_BENCH_N").ok().and_then(|s| s.parse().ok()).unwrap_or(16);
    let reg = OpRegistry::open_default()?;
    let params = RegParams::default();

    println!("== Table 8 analog: proposed GN-Krylov vs PyCA/deformetrica ==\n");
    let mut t = Table::new(&["data", "method", "#iter", "mism", "time[s]"]);

    for subject in ["na02", "na03", "na10"] {
        let prob = synth::nirep_analog_pair(&reg, n, subject)?;

        // PyCA analog: gradient descent at increasing iteration budgets
        // (the paper varies 100..1000 GD steps).
        for iters in [25, 50, 100] {
            let r = run_baseline(&reg, &prob, &params, BaselineKind::GradientDescent, iters)?;
            t.row(&[
                subject.into(),
                format!("gd (PyCA-like), cap {iters}"),
                r.iters.to_string(),
                format!("{:.1e}", r.mismatch_rel),
                fmt_time(r.time_s),
            ]);
        }
        // deformetrica analog: L-BFGS (paper default 50 iterations).
        for iters in [25, 50] {
            let r = run_baseline(&reg, &prob, &params, BaselineKind::Lbfgs, iters)?;
            t.row(&[
                subject.into(),
                format!("lbfgs (deformetrica-like), cap {iters}"),
                r.iters.to_string(),
                format!("{:.1e}", r.mismatch_rel),
                fmt_time(r.time_s),
            ]);
        }
        // The proposed method.
        let solver = GnSolver::new(&reg, params.clone());
        solver.precompile(n)?;
        let res = solver.solve(&prob)?;
        t.row(&[
            subject.into(),
            "proposed (GN-Krylov)".into(),
            res.iters.to_string(),
            format!("{:.1e}", res.mismatch_rel),
            fmt_time(res.time_s),
        ]);
    }
    t.print();
    println!("\n(expected shape per paper Table 8: the proposed method reaches a");
    println!(" mismatch an order of magnitude lower than the first-order");
    println!(" baselines at comparable or lower runtime; baseline mismatch");
    println!(" improves only slowly with more iterations.)");
    Ok(())
}
