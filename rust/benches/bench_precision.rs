//! Mixed-precision policy bench: full vs mixed at the two places the
//! policy touches the runtime — literal marshalling (f32 vs f16/bf16 at
//! the PJRT boundary) and the PCG Hessian-matvec loop (f32 vs fp16-
//! emulated matvec through the serve scheduler's stub executors).
//!
//! Host-side f16 is *emulation* (bit round-trips from `math/half.rs`): on
//! this substrate the win is the halved boundary bytes and the per-cache
//! (not per-matvec) conversion cost; the arithmetic speedup the paper
//! reports needs accelerator execution. The bench runs artifact-free and
//! writes a `BENCH_precision.json` summary.
//!
//! Run: `cargo bench --bench bench_precision`.

use std::time::Instant;

use claire::error::Result;
use claire::math::half;
use claire::optim::pcg::{self, PcgOptions};
use claire::serve::scheduler::stub_report;
use claire::serve::{worker_loop, Executor, JobPayload, JobSpec, Priority, Scheduler};
use claire::util::bench::Table;
use claire::util::json::Json;
use claire::Precision;

/// 3 * 64^3 f32 elements: one velocity-field cache tensor at the paper's
/// mid resolution.
const MARSHAL_ELEMS: usize = 3 * 64 * 64 * 64;
const MARSHAL_REPS: usize = 20;

struct MarshalRow {
    dtype: &'static str,
    bytes: usize,
    gb_per_s: f64,
}

fn bench_marshal() -> Vec<MarshalRow> {
    let data: Vec<f32> = (0..MARSHAL_ELEMS).map(|i| (i as f32 * 0.37).sin()).collect();
    let mut rows = Vec::new();

    // f32: the boundary copy the full-precision path pays per literal.
    let t0 = Instant::now();
    let mut sink = 0usize;
    for _ in 0..MARSHAL_REPS {
        let copied = data.clone();
        sink = sink.wrapping_add(copied.len());
    }
    let dt = t0.elapsed().as_secs_f64();
    rows.push(MarshalRow {
        dtype: "f32",
        bytes: MARSHAL_ELEMS * 4,
        gb_per_s: (MARSHAL_ELEMS * 4 * MARSHAL_REPS) as f64 / dt / 1e9,
    });

    // f16 / bf16: conversion at the boundary, half the payload bytes.
    let t0 = Instant::now();
    for _ in 0..MARSHAL_REPS {
        let bits = half::f16_bits_of(&data);
        sink = sink.wrapping_add(bits.len());
    }
    let dt = t0.elapsed().as_secs_f64();
    rows.push(MarshalRow {
        dtype: "f16",
        bytes: MARSHAL_ELEMS * 2,
        gb_per_s: (MARSHAL_ELEMS * 4 * MARSHAL_REPS) as f64 / dt / 1e9,
    });

    let t0 = Instant::now();
    for _ in 0..MARSHAL_REPS {
        let bits = half::bf16_bits_of(&data);
        sink = sink.wrapping_add(bits.len());
    }
    let dt = t0.elapsed().as_secs_f64();
    rows.push(MarshalRow {
        dtype: "bf16",
        bytes: MARSHAL_ELEMS * 2,
        gb_per_s: (MARSHAL_ELEMS * 4 * MARSHAL_REPS) as f64 / dt / 1e9,
    });
    assert!(sink > 0); // keep the loops observable
    rows
}

/// Stub executor running a small PCG solve whose matvec honors the job's
/// precision policy — the same split the GnSolver makes, minus PJRT.
struct PcgExec {
    dim: usize,
}

impl Executor for PcgExec {
    fn execute(
        &mut self,
        payload: &JobPayload,
        _cx: &claire::registration::SolveCx,
    ) -> Result<claire::serve::ExecOutcome> {
        let JobPayload::Spec(spec) = payload else {
            return Ok(stub_report("problem").into());
        };
        let dim = self.dim;
        let d: Vec<f32> = (0..dim).map(|i| 1.0 + i as f32 / dim as f32).collect();
        let b: Vec<f32> = (0..dim).map(|i| (i as f32 * 0.13).sin()).collect();
        let opts = PcgOptions {
            rtol: 1e-4,
            max_iter: 200,
            matvec_precision: spec.precision,
        };
        let res = pcg::solve(
            &b,
            opts,
            |p| {
                Ok(p.iter()
                    .zip(&d)
                    .map(|(&x, &dd)| match spec.precision {
                        Precision::Full => dd * x,
                        Precision::Mixed => half::f16_round(dd * x),
                    })
                    .collect())
            },
            |r| Ok(r.to_vec()),
        )?;
        assert_eq!(res.matvec_precision, spec.precision);
        Ok(stub_report(&spec.name()).into())
    }
}

struct SolveRow {
    precision: Precision,
    jobs: usize,
    wall_s: f64,
    jobs_per_s: f64,
}

fn bench_solves(precision: Precision, jobs: usize) -> SolveRow {
    let sched = Scheduler::new(jobs, 2);
    for i in 0..jobs {
        let spec = JobSpec {
            subject: ["na02", "na03", "na10"][i % 3].into(),
            precision,
            ..Default::default()
        };
        sched.submit(Priority::Batch, JobPayload::Spec(spec)).unwrap();
    }
    sched.shutdown(true);
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for w in 0..2 {
            let sched = sched.clone();
            scope.spawn(move || {
                let mut exec = PcgExec { dim: 1 << 14 };
                worker_loop(&sched, w, &mut exec);
            });
        }
    });
    let wall_s = t0.elapsed().as_secs_f64();
    SolveRow { precision, jobs, wall_s, jobs_per_s: jobs as f64 / wall_s.max(1e-12) }
}

fn main() {
    println!("== mixed-precision policy: marshalling + matvec throughput ==\n");

    let marshal = bench_marshal();
    let mut t = Table::new(&["dtype", "literal bytes", "GB(f32)/s"]);
    for r in &marshal {
        t.row(&[r.dtype.to_string(), r.bytes.to_string(), format!("{:.2}", r.gb_per_s)]);
    }
    t.print();
    println!("(f16/bf16 halve the boundary bytes; conversion is paid once per");
    println!(" Newton-iteration cache, not once per matvec — see solver.rs)\n");

    let jobs = 32usize;
    let solves = [
        bench_solves(Precision::Full, jobs),
        bench_solves(Precision::Mixed, jobs),
    ];
    let mut t = Table::new(&["precision", "jobs", "wall[s]", "jobs/s"]);
    for r in &solves {
        t.row(&[
            r.precision.as_str().to_string(),
            r.jobs.to_string(),
            format!("{:.3}", r.wall_s),
            format!("{:.1}", r.jobs_per_s),
        ]);
    }
    t.print();
    println!("\n(mixed matvec is f16 *emulation* host-side; the policy plumb-");
    println!(" through is what is measured, not accelerator arithmetic)");

    let summary = Json::object([
        ("bench", Json::str("precision")),
        (
            "marshal",
            Json::Arr(
                marshal
                    .iter()
                    .map(|r| {
                        Json::object([
                            ("dtype", Json::str(r.dtype)),
                            ("elems", Json::num(MARSHAL_ELEMS as f64)),
                            ("bytes", Json::num(r.bytes as f64)),
                            ("gb_per_s", Json::num(r.gb_per_s)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "solves",
            Json::Arr(
                solves
                    .iter()
                    .map(|r| {
                        Json::object([
                            ("precision", Json::str(r.precision.as_str())),
                            ("jobs", Json::num(r.jobs as f64)),
                            ("wall_s", Json::num(r.wall_s)),
                            ("jobs_per_s", Json::num(r.jobs_per_s)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    let out = "BENCH_precision.json";
    match std::fs::write(out, summary.render() + "\n") {
        Ok(()) => println!("\nsummary written to {out}"),
        Err(e) => eprintln!("\ncould not write {out}: {e}"),
    }
}
