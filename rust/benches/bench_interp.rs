//! Paper Table 3 + Table 4: interpolation kernels in the semi-Lagrangian
//! transport (runtime, effective bandwidth, accuracy).
//!
//! Table 3 analog: apply an LDDMM transformation to the synthetic brain
//! image forward in time, then backward, and compare to the original —
//! runtime and relative error per interpolation kernel variant.
//! Table 4 analog: per-call kernel runtime on scattered queries.
//!
//! Run: `cargo bench --bench bench_interp` (sizes via CLAIRE_BENCH_SIZES).

use claire::data::synth;
use claire::math::stats::rel_l2;
use claire::runtime::OpRegistry;
use claire::util::bench::{fmt_time, Bench, Table};
use claire::util::rng::Rng;

fn sizes() -> Vec<usize> {
    std::env::var("CLAIRE_BENCH_SIZES")
        .map(|s| s.split(',').filter_map(|x| x.parse().ok()).collect())
        .unwrap_or_else(|_| vec![16, 32, 64])
}

fn main() -> claire::Result<()> {
    let reg = OpRegistry::open_default()?;
    let bench = Bench::default();

    // ------------------------------------------------------------ Table 3
    // Forward+backward advection of the brain atlas per kernel variant.
    // The paper's variants map: CPU-LAG ~ ref-fft-cubic (jnp Lagrange),
    // GPU-TXTSPL ~ opt-fd8-cubic (prefiltered B-spline), GPU-TXTLIN ~
    // opt-fd8-linear (bf16 trilinear).
    println!("== Table 3 analog: semi-Lagrangian transport per interp kernel ==");
    let mut t3 = Table::new(&["N", "variant (paper analog)", "time[s]", "BW[GB/s]", "rel.err"]);
    for n in sizes() {
        let (atlas, _) = synth::brain_atlas(n);
        let v = synth::smooth_random_velocity(n, 42, 2, 0.5);
        for (variant, analog) in [
            ("ref-fft-cubic", "CPU/GPU-LAG"),
            ("opt-fft-cubic", "GPU-TXTSPL+FFT"),
            ("opt-fd8-cubic", "GPU-TXTSPL"),
            ("opt-fd8-linear", "GPU-TXTLIN"),
        ] {
            let op = reg.get("transport", variant, n)?;
            let mut back = Vec::new();
            let neg: Vec<f32> = v.data.iter().map(|x| -x).collect();
            let s = bench.run(variant, || {
                let fwd = op.call(&[&v.data, &atlas.data]).unwrap().remove(0);
                back = op.call(&[&neg, &fwd]).unwrap().remove(0);
            });
            let err = rel_l2(&back, &atlas.data);
            // Two transport solves = 14 interpolation kernel calls total
            // (paper Table 3 protocol); MOPS model 20 B/point per call.
            let bytes = 14 * 20 * n * n * n;
            t3.row(&[
                format!("{n}^3"),
                format!("{variant} ({analog})"),
                fmt_time(s.median_s),
                format!("{:.1}", s.throughput_gbs(bytes)),
                format!("{err:.1e}"),
            ]);
        }
    }
    t3.print();

    // ------------------------------------------------------------ Table 4
    println!("\n== Table 4 analog: per-call interpolation kernel time ==");
    let mut t4 = Table::new(&["N", "kernel", "t_syn[s]", "BW[GB/s]"]);
    for n in sizes() {
        let m = n * n * n;
        let mut rng = Rng::new(5);
        let f: Vec<f32> = (0..m).map(|_| rng.uniform_f32(0.0, 1.0)).collect();
        let q: Vec<f32> = (0..3 * m).map(|_| rng.uniform_f32(0.0, n as f32)).collect();
        for op_name in ["interp_lin", "interp_linbf16", "interp_lag", "interp_spl", "interp_lag_jnp"]
        {
            let op = reg.get(op_name, "opt-fd8-cubic", n)?;
            let s = bench.run(op_name, || {
                op.call(&[&f, &q]).unwrap();
            });
            t4.row(&[
                format!("{n}^3"),
                op_name.into(),
                fmt_time(s.median_s),
                format!("{:.1}", s.throughput_gbs(20 * m)),
            ]);
        }
    }
    t4.print();
    println!("\n(expected shape per paper: TXTLIN < TXTSPL < TXTLAG < LAG-jnp runtime;");
    println!(" roundtrip error: TXTSPL < LAG < TXTLIN.)");
    Ok(())
}
