//! Paper Table 5 + Figure 2: first-order derivative kernels.
//!
//! Table 5 analog: runtime per gradient/divergence call, FFT vs FD8, per
//! grid size. Figure 2 analog: L2 error of both schemes over frequency
//! (series written to `fig2_bench.csv`).
//!
//! Run: `cargo bench --bench bench_derivatives`.

use std::io::Write;

use claire::math::kernels_ref;
use claire::math::stats::rel_l2;
use claire::runtime::OpRegistry;
use claire::util::bench::{fmt_time, Bench, Table};
use claire::util::rng::Rng;

fn sizes() -> Vec<usize> {
    std::env::var("CLAIRE_BENCH_SIZES")
        .map(|s| s.split(',').filter_map(|x| x.parse().ok()).collect())
        .unwrap_or_else(|_| vec![16, 32, 64])
}

fn main() -> claire::Result<()> {
    let reg = OpRegistry::open_default()?;
    let bench = Bench::default();

    // ------------------------------------------------------------ Table 5
    println!("== Table 5 analog: grad/div runtime, FFT vs FD8 ==");
    let mut t5 = Table::new(&["N", "operator", "FFT[s]", "FD8[s]", "speedup"]);
    for n in sizes() {
        let m = n * n * n;
        let mut rng = Rng::new(3);
        let f: Vec<f32> = (0..m).map(|_| rng.uniform_f32(-1.0, 1.0)).collect();
        let w: Vec<f32> = (0..3 * m).map(|_| rng.uniform_f32(-1.0, 1.0)).collect();

        let g_fft = reg.get("grad_fft", "opt-fd8-cubic", n)?;
        let g_fd8 = reg.get("grad_fd8", "opt-fd8-cubic", n)?;
        let s_fft = bench.run("grad_fft", || {
            g_fft.call(&[&f]).unwrap();
        });
        let s_fd8 = bench.run("grad_fd8", || {
            g_fd8.call(&[&f]).unwrap();
        });
        t5.row(&[
            format!("{n}^3"),
            "grad".into(),
            fmt_time(s_fft.median_s),
            fmt_time(s_fd8.median_s),
            format!("{:.1}x", s_fft.median_s / s_fd8.median_s),
        ]);

        let d_fft = reg.get("div_fft", "opt-fd8-cubic", n)?;
        let d_fd8 = reg.get("div_fd8", "opt-fd8-cubic", n)?;
        let s_fft = bench.run("div_fft", || {
            d_fft.call(&[&w]).unwrap();
        });
        let s_fd8 = bench.run("div_fd8", || {
            d_fd8.call(&[&w]).unwrap();
        });
        t5.row(&[
            format!("{n}^3"),
            "div".into(),
            fmt_time(s_fft.median_s),
            fmt_time(s_fd8.median_s),
            format!("{:.1}x", s_fft.median_s / s_fd8.median_s),
        ]);
    }
    t5.print();
    println!("(paper Table 5: FD8 is 3.2-4.7x faster than FFT on the V100)");

    // ------------------------------------------------------------ Fig 2
    println!("\n== Figure 2 analog: accuracy over frequency ==");
    let mut csv = String::from("n,omega,err_fd8,err_fft\n");
    let mut crossover_seen = false;
    for n in sizes() {
        let m = n * n * n;
        let g_fft = reg.get("grad_fft", "opt-fd8-cubic", n)?;
        let g_fd8 = reg.get("grad_fd8", "opt-fd8-cubic", n)?;
        let mut last: Option<(f64, f64)> = None;
        for omega in 1..(n / 2) {
            let f = kernels_ref::fig2_probe(n, omega as f64);
            let want = kernels_ref::fig2_probe_deriv(n, omega as f64);
            let e8 = rel_l2(&g_fd8.call(&[&f])?.remove(0)[2 * m..], &want);
            let ef = rel_l2(&g_fft.call(&[&f])?.remove(0)[2 * m..], &want);
            csv.push_str(&format!("{n},{omega},{e8:.3e},{ef:.3e}\n"));
            last = Some((e8, ef));
            if e8 > 10.0 * ef {
                crossover_seen = true;
            }
        }
        if let Some((e8, ef)) = last {
            println!(
                "n={n}: near-Nyquist FD8 err {e8:.1e} vs FFT err {ef:.1e} \
                 (FD8 degrades at high frequency — paper Fig 2 shape)"
            );
        }
    }
    std::fs::File::create("fig2_bench.csv")?.write_all(csv.as_bytes())?;
    println!("series -> fig2_bench.csv; high-frequency FD8 degradation seen: {crossover_seen}");
    Ok(())
}
