//! Template-building bench: end-to-end round latency of the group-wise
//! atlas loop (`template::TemplateDriver` against an in-process daemon
//! over the real wire protocol, stub registrations), sweeping cohort
//! size, plus the raw server-side reduction kernels (`mean_scalar`,
//! `log_mean`, exponential + warp) the `reduce` verb dispatches to.
//!
//! The stub executor makes registration free, so the end-to-end sweep
//! isolates what the tentpole added: batch admission, retained-output
//! bookkeeping, the reduce round-trip, and journaling — per round and
//! per subject. Writes a `BENCH_template.json` summary.
//!
//! Run: `cargo bench --bench bench_template`. Set `CLAIRE_BENCH_SMOKE=1`
//! to shrink the sweep to a seconds-scale CI smoke run.

use std::sync::Arc;
use std::time::Instant;

use claire::error::Result;
use claire::field::{Field3, VecField3};
use claire::registration::groupwise::{exponential, log_mean, mean_scalar, warp_scalar};
use claire::serve::{
    scheduler::stub_report, Client, Daemon, DaemonConfig, ExecOutcome, Executor,
    ExecutorFactory, JobPayload, VolumeStore,
};
use claire::template::{TemplateConfig, TemplateDriver};
use claire::util::bench::Table;
use claire::util::json::Json;

/// Free-registration stub that still exercises the data plane: retains a
/// warped image (midpoint blend) and a small constant velocity for every
/// uploaded-source job, so rounds run the velocity reduce path.
struct RetainExec {
    store: Option<Arc<VolumeStore>>,
}

impl Executor for RetainExec {
    fn attach_store(&mut self, store: Arc<VolumeStore>) {
        self.store = Some(store);
    }

    fn execute(
        &mut self,
        payload: &JobPayload,
        _cx: &claire::registration::SolveCx,
    ) -> Result<ExecOutcome> {
        let JobPayload::Volumes { spec, m0, m1, .. } = payload else {
            return Ok(stub_report("synthetic").into());
        };
        let store = self.store.as_ref().expect("store attached");
        let n = spec.n;
        let warped: Vec<f32> =
            m0.data.iter().zip(&m1.data).map(|(t, s)| 0.5 * (t + s)).collect();
        let wrec = store.put(n, warped)?;
        let c = 0.01 * (1.0 + m1.data[0]);
        let vrec = store.put_vec(n, vec![c; 3 * n * n * n])?;
        let mut out = ExecOutcome::from(stub_report(&spec.name()));
        out.warped = Some(wrec.id);
        out.velocity = Some(vrec.id);
        Ok(out)
    }
}

fn retain_factory() -> ExecutorFactory {
    Arc::new(|_w| Ok(Box::new(RetainExec { store: None }) as Box<dyn Executor>))
}

struct RoundRow {
    subjects: usize,
    rounds: usize,
    wall_s: f64,
    round_ms: f64,
    per_subject_ms: f64,
}

/// One end-to-end sweep point: upload `subjects` cohort volumes, build a
/// template for `rounds` rounds (tol 0 — never converges early, so the
/// denominator is fixed), report wall time per round and per subject.
fn run_template_once(subjects: usize, rounds: usize, n: usize) -> RoundRow {
    let cfg = DaemonConfig {
        addr: "127.0.0.1:0".into(),
        workers: 2,
        queue_cap: 2 * subjects.max(16),
        journal: None,
        ..Default::default()
    };
    let handle = Daemon::start(cfg, retain_factory()).unwrap();
    let addr = handle.addr().to_string();
    let mut uploader = Client::connect(&addr).unwrap();
    uploader.hello().unwrap();
    let ids: Vec<String> = (0..subjects)
        .map(|i| {
            let data: Vec<f32> =
                (0..n * n * n).map(|v| ((v + i * 7919) as f32 * 0.13).sin().abs()).collect();
            uploader.upload(n, &data).unwrap().id
        })
        .collect();

    let mut driver_client = Client::connect(&addr).unwrap();
    driver_client.hello().unwrap();
    let tcfg = TemplateConfig { rounds, tol: 0.0, ..Default::default() };
    let mut driver = TemplateDriver::new(driver_client, ids, tcfg).unwrap();
    let t0 = Instant::now();
    let outcomes = driver.run(|_| {}).unwrap();
    let wall_s = t0.elapsed().as_secs_f64();
    assert_eq!(outcomes.len(), rounds, "tol 0 runs the full budget");

    uploader.shutdown(true).unwrap();
    handle.join().unwrap();
    RoundRow {
        subjects,
        rounds,
        wall_s,
        round_ms: wall_s * 1e3 / rounds as f64,
        per_subject_ms: wall_s * 1e3 / (rounds * subjects) as f64,
    }
}

struct KernelRow {
    n: usize,
    k: usize,
    mean_scalar_ms: f64,
    log_mean_ms: f64,
    exp_warp_ms: f64,
}

/// Raw reduction kernels at grid size `n`, cohort size `k` — the
/// server-side cost of one `reduce` call, without wire or scheduler.
fn run_kernel_bench(n: usize, k: usize, iters: usize) -> KernelRow {
    let imgs: Vec<Field3> = (0..k)
        .map(|s| {
            Field3::from_vec(
                n,
                (0..n * n * n).map(|v| ((v + s * 131) as f32 * 0.07).sin()).collect(),
            )
            .unwrap()
        })
        .collect();
    let vels: Vec<VecField3> = (0..k)
        .map(|s| {
            VecField3::from_vec(
                n,
                (0..3 * n * n * n).map(|v| ((v + s * 977) as f32 * 0.03).sin() * 0.1).collect(),
            )
            .unwrap()
        })
        .collect();
    let img_refs: Vec<&Field3> = imgs.iter().collect();
    let vel_refs: Vec<&VecField3> = vels.iter().collect();

    let t0 = Instant::now();
    for _ in 0..iters {
        std::hint::black_box(mean_scalar(&img_refs).unwrap());
    }
    let mean_scalar_ms = t0.elapsed().as_secs_f64() * 1e3 / iters as f64;

    let t0 = Instant::now();
    for _ in 0..iters {
        std::hint::black_box(log_mean(&vel_refs).unwrap());
    }
    let log_mean_ms = t0.elapsed().as_secs_f64() * 1e3 / iters as f64;

    let vbar = log_mean(&vel_refs).unwrap();
    let t0 = Instant::now();
    for _ in 0..iters {
        let disp = exponential(&vbar);
        std::hint::black_box(warp_scalar(&imgs[0], &disp).unwrap());
    }
    let exp_warp_ms = t0.elapsed().as_secs_f64() * 1e3 / iters as f64;

    KernelRow { n, k, mean_scalar_ms, log_mean_ms, exp_warp_ms }
}

fn main() {
    let smoke = std::env::var("CLAIRE_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    if smoke {
        println!("[smoke mode: CLAIRE_BENCH_SMOKE=1 — reduced sweep sizes]\n");
    }

    let n = 16usize;
    let rounds = if smoke { 2usize } else { 4usize };
    let cohorts: &[usize] = if smoke { &[4] } else { &[4, 8, 16] };
    println!("== template loop: {n}^3 subjects, {rounds} rounds, stub registration ==\n");
    let mut table =
        Table::new(&["subjects", "rounds", "wall[s]", "round[ms]", "per-subject[ms]"]);
    let mut rows = Vec::new();
    for &subjects in cohorts {
        run_template_once(subjects, 1, n); // warmup: daemon spawn + allocator
        let row = run_template_once(subjects, rounds, n);
        table.row(&[
            row.subjects.to_string(),
            row.rounds.to_string(),
            format!("{:.3}", row.wall_s),
            format!("{:.1}", row.round_ms),
            format!("{:.2}", row.per_subject_ms),
        ]);
        rows.push(row);
    }
    table.print();
    println!("\n(per-round cost = batch admission + N retained solves + one reduce");
    println!(" + journal append; stub solves are free, so per-subject ms is the");
    println!(" orchestration overhead the template subsystem adds per cohort member)");

    let kn = if smoke { 16usize } else { 32usize };
    let kk = 8usize;
    let kiters = if smoke { 4usize } else { 16usize };
    println!("\n== reduction kernels: {kn}^3, cohort {kk} ==\n");
    run_kernel_bench(kn, kk, 1); // warmup
    let kr = run_kernel_bench(kn, kk, kiters);
    let mut kt = Table::new(&["n", "k", "mean_scalar[ms]", "log_mean[ms]", "exp+warp[ms]"]);
    kt.row(&[
        kr.n.to_string(),
        kr.k.to_string(),
        format!("{:.3}", kr.mean_scalar_ms),
        format!("{:.3}", kr.log_mean_ms),
        format!("{:.3}", kr.exp_warp_ms),
    ]);
    kt.print();
    println!("\n(mean_scalar / log_mean are single-pass f64 accumulations; exp+warp");
    println!(" pays scaling-and-squaring plus one trilinear gather — the dominant");
    println!(" server-side cost of a velocity-mode reduce with apply)");

    let summary = Json::object([
        ("bench", Json::str("template")),
        ("n", Json::num(n as f64)),
        ("rounds", Json::num(rounds as f64)),
        (
            "sweeps",
            Json::Arr(
                rows.iter()
                    .map(|r| {
                        Json::object([
                            ("subjects", Json::num(r.subjects as f64)),
                            ("wall_s", Json::num(r.wall_s)),
                            ("round_ms", Json::num(r.round_ms)),
                            ("per_subject_ms", Json::num(r.per_subject_ms)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "kernels",
            Json::object([
                ("n", Json::num(kr.n as f64)),
                ("k", Json::num(kr.k as f64)),
                ("mean_scalar_ms", Json::num(kr.mean_scalar_ms)),
                ("log_mean_ms", Json::num(kr.log_mean_ms)),
                ("exp_warp_ms", Json::num(kr.exp_warp_ms)),
            ]),
        ),
    ]);
    let out = "BENCH_template.json";
    match std::fs::write(out, summary.render() + "\n") {
        Ok(()) => println!("\nsummary written to {out}"),
        Err(e) => eprintln!("\ncould not write {out}: {e}"),
    }
}
