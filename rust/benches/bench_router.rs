//! Fleet-router overhead bench: the same wire operations measured
//! directly against a daemon and through the router tier in front of it.
//!
//! Reports p50/p95 submit round-trip latency direct vs routed (the
//! routing tax: one extra hop, placement lookup, routing-table insert),
//! and submit-to-terminal-event watch latency direct vs routed (the
//! federation tax: backend watcher -> id translation -> fan -> forwarder
//! thread). Stub executors as in `bench_service` — this measures the
//! tier, not solves. Writes a `BENCH_router.json` summary.
//!
//! Run: `cargo bench --bench bench_router`.

use std::sync::Arc;
use std::time::{Duration, Instant};

use claire::error::Result;
use claire::math::stats::percentile_sorted;
use claire::serve::scheduler::stub_report;
use claire::serve::{
    Client, Daemon, DaemonConfig, DaemonHandle, EventMsg, Executor, ExecutorFactory,
    JobPayload, JobSpec, Router, RouterConfig, RouterHandle,
};
use claire::util::bench::Table;
use claire::util::json::Json;

struct StubExec;

impl Executor for StubExec {
    fn execute(
        &mut self,
        payload: &JobPayload,
        _cx: &claire::registration::SolveCx,
    ) -> Result<claire::serve::ExecOutcome> {
        let ms = match payload {
            JobPayload::Spec(s) => s.max_iter.unwrap_or(1) as u64,
            _ => 1,
        };
        std::thread::sleep(Duration::from_millis(ms));
        Ok(stub_report(&payload.name()).into())
    }
}

fn stub_factory() -> ExecutorFactory {
    Arc::new(|_w| Ok(Box::new(StubExec) as Box<dyn Executor>))
}

fn start_daemon(node_id: &str) -> DaemonHandle {
    Daemon::start(
        DaemonConfig {
            addr: "127.0.0.1:0".into(),
            workers: 1,
            queue_cap: 512,
            journal: None,
            node_id: Some(node_id.into()),
            ..Default::default()
        },
        stub_factory(),
    )
    .unwrap()
}

fn connect(addr: &str) -> Client {
    let mut c = Client::connect_with_timeout(addr, Duration::from_secs(10)).unwrap();
    c.set_io_timeout(Some(Duration::from_secs(30))).unwrap();
    c.negotiate().unwrap();
    c
}

fn spec(i: usize) -> JobSpec {
    JobSpec { subject: format!("bench{i}"), max_iter: Some(1), ..Default::default() }
}

/// p50/p95 of one submit round trip (request line out, response line in).
fn submit_latency(client: &mut Client, iters: usize) -> (f64, f64) {
    let mut lat_us = Vec::with_capacity(iters);
    for i in 0..iters {
        let t0 = Instant::now();
        client.submit(&spec(i)).unwrap();
        lat_us.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    lat_us.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (percentile_sorted(&lat_us, 50.0), percentile_sorted(&lat_us, 95.0))
}

/// p50/p95 of submit-return -> terminal-event-arrival on a watch stream
/// (one job in flight at a time, so queue wait is just the ~1 ms stub
/// service; the rest is event-plane delivery).
fn watch_latency(client: &mut Client, watcher: &mut Client, iters: usize) -> (f64, f64) {
    let mut lat_ms = Vec::with_capacity(iters);
    for i in 0..iters {
        let id = client.submit(&spec(i)).unwrap();
        let t0 = Instant::now();
        loop {
            match watcher.next_event().unwrap() {
                EventMsg::Job { id: got, state, .. } if got == id && state.is_terminal() => break,
                _ => {}
            }
        }
        lat_ms.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    lat_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (percentile_sorted(&lat_ms, 50.0), percentile_sorted(&lat_ms, 95.0))
}

fn drain(client: &mut Client) {
    let t0 = Instant::now();
    loop {
        let s = client.stats().unwrap();
        if s.queued == 0 && s.running == 0 {
            return;
        }
        assert!(t0.elapsed().as_secs() < 120, "fleet never drained");
        std::thread::sleep(Duration::from_millis(20));
    }
}

fn main() {
    let submits = 64usize;
    let watches = 16usize;

    let a = start_daemon("bench-a");
    let b = start_daemon("bench-b");
    let router: RouterHandle = Router::start(RouterConfig {
        addr: "127.0.0.1:0".into(),
        backends: vec![a.addr().to_string(), b.addr().to_string()],
        probe_interval: Duration::from_millis(200),
        ..RouterConfig::default()
    })
    .unwrap();

    // Let the router's backend watchers subscribe before measuring the
    // event plane.
    std::thread::sleep(Duration::from_millis(300));

    let mut direct = connect(&a.addr().to_string());
    let mut routed = connect(&router.addr().to_string());

    println!("== fleet router overhead: 2 backends, stub 1 ms jobs ==\n");

    // Warmup both paths (connect caches, allocator, first-probe effects).
    submit_latency(&mut direct, 8);
    submit_latency(&mut routed, 8);
    drain(&mut direct);
    drain(&mut routed);

    let (d50, d95) = submit_latency(&mut direct, submits);
    drain(&mut direct);
    let (r50, r95) = submit_latency(&mut routed, submits);
    drain(&mut routed);

    let mut t = Table::new(&["path", "p50 [us]", "p95 [us]"]);
    t.row(&["submit direct".into(), format!("{d50:.0}"), format!("{d95:.0}")]);
    t.row(&["submit routed".into(), format!("{r50:.0}"), format!("{r95:.0}")]);
    t.print();
    println!(
        "\n(routing overhead p50: {:.0} us = extra hop + placement + routing-table insert)\n",
        r50 - d50
    );

    let mut direct_watch = connect(&a.addr().to_string());
    direct_watch.watch().unwrap();
    let mut routed_watch = connect(&router.addr().to_string());
    routed_watch.watch().unwrap();

    let (wd50, wd95) = watch_latency(&mut direct, &mut direct_watch, watches);
    let (wr50, wr95) = watch_latency(&mut routed, &mut routed_watch, watches);

    let mut wt = Table::new(&["path", "p50 [ms]", "p95 [ms]"]);
    wt.row(&["watch direct".into(), format!("{wd50:.2}"), format!("{wd95:.2}")]);
    wt.row(&["watch routed".into(), format!("{wr50:.2}"), format!("{wr95:.2}")]);
    wt.print();
    println!("\n(both include the ~1 ms stub solve; the delta is the fan-in tax:");
    println!(" backend watcher -> global-id translation -> fan -> forwarder)");

    let summary = Json::object([
        ("bench", Json::str("router")),
        ("backends", Json::num(2.0)),
        ("submits", Json::num(submits as f64)),
        (
            "submit_us",
            Json::object([
                ("direct_p50", Json::num(d50)),
                ("direct_p95", Json::num(d95)),
                ("routed_p50", Json::num(r50)),
                ("routed_p95", Json::num(r95)),
                ("overhead_p50", Json::num(r50 - d50)),
            ]),
        ),
        (
            "watch_ms",
            Json::object([
                ("direct_p50", Json::num(wd50)),
                ("direct_p95", Json::num(wd95)),
                ("routed_p50", Json::num(wr50)),
                ("routed_p95", Json::num(wr95)),
                ("overhead_p50", Json::num(wr50 - wd50)),
            ]),
        ),
    ]);
    let out = "BENCH_router.json";
    match std::fs::write(out, summary.render() + "\n") {
        Ok(()) => println!("\nsummary written to {out}"),
        Err(e) => eprintln!("\ncould not write {out}: {e}"),
    }

    // Drain the fleet through the router (also stops the router tier).
    routed.shutdown(true).unwrap();
    router.join().unwrap();
    a.join().unwrap();
    b.join().unwrap();
}
