//! L3 hot-path vector algebra throughput (PCG axpy/dot/fused kernels).
//!
//! Not a paper table per se, but the coordinator-side roofline check the
//! perf pass (EXPERIMENTS.md section Perf) tracks: the PCG vector ops must
//! not be the bottleneck next to the PJRT operator calls.
//!
//! Run: `cargo bench --bench bench_fieldops`.

use claire::field::ops;
use claire::util::bench::{Bench, Table};
use claire::util::rng::Rng;

fn main() {
    let bench = Bench { warmup: 3, samples: 11 };
    let mut t = Table::new(&["op", "len", "time[us]", "GB/s"]);
    for n in [16usize, 32, 64] {
        let len = 3 * n * n * n;
        let mut rng = Rng::new(1);
        let x: Vec<f32> = (0..len).map(|_| rng.uniform_f32(-1.0, 1.0)).collect();
        let q: Vec<f32> = (0..len).map(|_| rng.uniform_f32(-1.0, 1.0)).collect();
        let mut y: Vec<f32> = (0..len).map(|_| rng.uniform_f32(-1.0, 1.0)).collect();

        let s = bench.run("axpy", || ops::axpy(0.5, &x, &mut y));
        t.row(&[
            "axpy".into(),
            format!("3x{n}^3"),
            format!("{:.1}", s.median_s * 1e6),
            format!("{:.1}", s.throughput_gbs(12 * len)),
        ]);

        let mut acc = 0.0;
        let s = bench.run("dot", || acc += ops::dot(&x, &q));
        std::hint::black_box(acc);
        t.row(&[
            "dot".into(),
            format!("3x{n}^3"),
            format!("{:.1}", s.median_s * 1e6),
            format!("{:.1}", s.throughput_gbs(8 * len)),
        ]);

        let mut acc = 0.0;
        let s = bench.run("axpy_dot_self", || acc += ops::axpy_dot_self(-0.5, &q, &mut y));
        std::hint::black_box(acc);
        t.row(&[
            "axpy+dot fused".into(),
            format!("3x{n}^3"),
            format!("{:.1}", s.median_s * 1e6),
            format!("{:.1}", s.throughput_gbs(12 * len)),
        ]);

        let s = bench.run("norm2", || acc += ops::norm2(&x));
        std::hint::black_box(acc);
        t.row(&[
            "norm2".into(),
            format!("3x{n}^3"),
            format!("{:.1}", s.median_s * 1e6),
            format!("{:.1}", s.throughput_gbs(4 * len)),
        ]);
    }
    t.print();
    println!("\n(fused axpy+dot saves one full pass over r vs separate calls;");
    println!(" see EXPERIMENTS.md section Perf for the L3 iteration log.)");
}
