//! Paper Table 7: full registration runs across kernel variants, datasets
//! and grid sizes — det F stats, DICE before/after, mismatch, gradient
//! reduction, iteration/matvec counts, solver runtime.
//!
//! Default sweep: all four variants x {na02,na03,na10} at 16^3 plus all
//! variants x na02 at 32^3 (64^3 rows live in EXPERIMENTS.md; enable with
//! CLAIRE_BENCH_FULL=1).
//!
//! Run: `cargo bench --bench bench_registration`.

use claire::data::synth;
use claire::registration::{GnSolver, RegParams, RunReport};
use claire::runtime::OpRegistry;
use claire::util::bench::Table;

fn main() -> claire::Result<()> {
    let full = std::env::var("CLAIRE_BENCH_FULL").is_ok();
    let reg = OpRegistry::open_default()?;
    let variants = ["ref-fft-cubic", "opt-fft-cubic", "opt-fd8-cubic", "opt-fd8-linear"];

    let mut cases: Vec<(usize, &str, &str)> = Vec::new();
    for v in variants {
        for s in ["na02", "na03", "na10"] {
            cases.push((16, v, s));
        }
        cases.push((32, v, "na02"));
    }
    if full {
        for v in variants {
            cases.push((64, v, "na02"));
        }
    }

    println!("== Table 7 analog: registration quality & performance ==");
    println!("(solver times exclude one-time XLA compilation, like the paper's");
    println!(" runtimes exclude the CUDA build; compile time reported separately)\n");

    let mut table = Table::new(&{
        let mut h = vec!["N"];
        h.extend(RunReport::headers());
        h
    });
    let mut compile_s = 0.0;
    for (n, variant, subject) in cases {
        let params = RegParams { variant: variant.into(), ..Default::default() };
        let solver = GnSolver::new(&reg, params);
        compile_s += solver.precompile(n)?;
        let prob = synth::nirep_analog_pair(&reg, n, subject)?;
        let res = solver.solve(&prob)?;
        let report = RunReport::build(&solver, &prob, &res)?;
        let mut row = vec![format!("{n}^3")];
        row.extend(report.row());
        table.row(&row);
    }
    table.print();
    println!("\ntotal one-time compile time across variants: {compile_s:.1}s");
    println!("(expected shape per paper Table 7: iteration counts and quality");
    println!(" metrics nearly identical across variants; opt-fd8-linear fastest,");
    println!(" with slightly larger max det F; ref-fft-cubic slowest.)");
    Ok(())
}
