//! Clinical-workflow batch driver: the paper's deployment setting.
//!
//! "Clinical workflows require high-throughput, with one or more
//! registration tasks per node ... multiple registration tasks can take
//! place in an embarrassingly parallel way" (paper section 5). This example
//! submits a population-study style batch (3 subjects x 2 variants) to the
//! thread-pool coordinator and reports throughput scaling over worker
//! counts.
//!
//! ```bash
//! cargo run --release --example clinical_batch -- [n] [max_workers]
//! ```
//!
//! With `CLAIRE_SERVE_ADDR` set (e.g. after `claire serve`), the same
//! population-study batch is submitted to the live daemon over the NDJSON
//! wire protocol instead of an in-process pool — the deployment shape:
//! compilation stays warm in the daemon across study batches.
//!
//! ```bash
//! claire serve --workers 4 &
//! CLAIRE_SERVE_ADDR=127.0.0.1:7464 cargo run --release --example clinical_batch -- 16
//! ```

use claire::coordinator::{poisson_arrivals, simulate_queue, summarize, BatchService, Job};
use claire::data::synth;
use claire::registration::{RegParams, RunReport};
use claire::runtime::OpRegistry;
use claire::serve::{Client, JobSpec, Priority};
use claire::util::bench::Table;

/// Run the study batch against a live daemon over the wire protocol.
fn run_against_daemon(addr: &str, n: usize) -> claire::Result<()> {
    let mut client = Client::connect(addr)?;
    client.ping()?;
    println!("daemon batch: submitting 3 subjects x 2 variants at {n}^3 to {addr}");
    let mut ids = Vec::new();
    for variant in ["opt-fd8-cubic", "opt-fd8-linear"] {
        for subject in ["na02", "na03", "na10"] {
            let spec = JobSpec {
                subject: subject.into(),
                n,
                variant: variant.into(),
                priority: Priority::Batch,
                ..Default::default()
            };
            ids.push(client.submit(&spec)?);
        }
    }
    // Wait on *our* job ids, not daemon-global idleness: the daemon may
    // be serving other clients concurrently (that's its purpose).
    let views = ids
        .into_iter()
        .map(|id| client.wait_terminal(id, 600.0))
        .collect::<claire::Result<Vec<_>>>()?;
    let stats = client.stats()?;
    claire::serve::client::job_table(&views).print();
    println!(
        "daemon stats: {} done / {} failed; op cache {} compiles, {} warm hits \
         (reuse is the daemon's whole point: later batches skip compilation)",
        stats.completed, stats.failed, stats.cache_compiles, stats.cache_hits
    );
    Ok(())
}

fn main() -> claire::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(16);
    let max_workers: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(4);

    if let Ok(addr) = std::env::var("CLAIRE_SERVE_ADDR") {
        return run_against_daemon(&addr, n);
    }

    // Job generation uses its own registry; workers open their own.
    let reg = OpRegistry::open_default()?;
    let mut jobs = Vec::new();
    for variant in ["opt-fd8-cubic", "opt-fd8-linear"] {
        for subject in ["na02", "na03", "na10"] {
            let problem = synth::nirep_analog_pair(&reg, n, subject)?;
            let params = RegParams { variant: variant.into(), ..Default::default() };
            jobs.push(Job { id: jobs.len(), problem, params });
        }
    }
    drop(reg);
    println!("batch: {} registration jobs at {n}^3", jobs.len());

    let mut scaling = Table::new(&["workers", "wall[s]", "serial-eq[s]", "reg/s", "ok"]);
    let mut workers = 1;
    while workers <= max_workers {
        let svc = BatchService::new_default(workers);
        let rep = svc.run(jobs.clone())?;
        scaling.row(&[
            workers.to_string(),
            format!("{:.2}", rep.wall_s),
            format!("{:.2}", rep.serial_time()),
            format!("{:.3}", rep.throughput()),
            format!("{}/{}", rep.succeeded(), rep.outcomes.len()),
        ]);
        if workers == max_workers {
            println!("\nper-job reports (workers = {workers}):");
            let mut t = Table::new(&RunReport::headers());
            for o in &rep.outcomes {
                if let Some(r) = &o.report {
                    t.row(&r.row());
                }
            }
            t.print();
        }
        workers *= 2;
    }
    println!("\nthroughput scaling (includes per-worker one-time compiles):");
    scaling.print();

    // --- Study-scale extrapolation (paper section 1 motivation) ---------
    // Use the measured mean per-job solve time to size a clinical study:
    // Poisson arrivals over an 8-hour shift, M/D/c queueing per node.
    let svc = BatchService::new_default(1);
    let probe = svc.run(vec![Job {
        id: 0,
        problem: synth::nirep_analog_pair(&OpRegistry::open_default()?, n, "na02")?,
        params: RegParams::default(),
    }])?;
    let service_s = probe
        .outcomes
        .first()
        .and_then(|o| o.report.as_ref().map(|r| r.time_s))
        .unwrap_or(5.0);
    println!("\nstudy-scale queueing extrapolation (measured service {service_s:.2}s/job):");
    let mut q = Table::new(&["arrivals/min", "workers", "p50 lat[s]", "p95 lat[s]", "mean wait[s]"]);
    for rate_min in [1.0, 4.0, 12.0] {
        for workers in [1usize, 2, 4] {
            let reqs = poisson_arrivals(7, rate_min / 60.0, 8.0 * 3600.0, &["na02", "na03", "na10"]);
            let served = simulate_queue(&reqs, service_s, workers);
            let s = summarize(&served);
            q.row(&[
                format!("{rate_min}"),
                workers.to_string(),
                format!("{:.2}", s.p50_s),
                format!("{:.2}", s.p95_s),
                format!("{:.2}", s.mean_wait_s),
            ]);
        }
    }
    q.print();
    println!("(the paper's claim in queueing terms: cutting service time from");
    println!(" minutes to seconds keeps p95 latency flat at study-scale rates)");
    Ok(())
}
