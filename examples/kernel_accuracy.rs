//! Kernel accuracy & intensity study (paper Table 2, Table 4, Figure 2).
//!
//! * Table 2: analytic FLOPS/MOPS intensity model for every interpolation
//!   kernel, with our measured effective bandwidth standing in for the
//!   NVIDIA Visual Profiler column.
//! * Table 4: relative interpolation error + per-call runtime on the
//!   analytic probe `(sin^2(8 x1) + sin^2(2 x2) + sin^2(4 x3)) / 3`,
//!   evaluated on a randomly perturbed grid.
//! * Figure 2: L2 error of FFT vs FD8 first derivatives over frequency
//!   (CSV written to `fig2_accuracy.csv`).
//!
//! ```bash
//! cargo run --release --example kernel_accuracy -- [sizes]
//! ```

use std::f64::consts::PI;
use std::io::Write;

use claire::math::kernels_ref;
use claire::math::stats::rel_l2;
use claire::registration::intensity::{our_kernels, paper_kernels, V100};
use claire::runtime::OpRegistry;
use claire::util::bench::{fmt_time, Bench, Table};
use claire::util::rng::Rng;

fn probe_field(n: usize) -> Vec<f32> {
    let mut f = vec![0f32; n * n * n];
    for i in 0..n {
        for j in 0..n {
            for k in 0..n {
                let (x1, x2, x3) = (
                    2.0 * PI * i as f64 / n as f64,
                    2.0 * PI * j as f64 / n as f64,
                    2.0 * PI * k as f64 / n as f64,
                );
                f[(i * n + j) * n + k] = (((8.0 * x1).sin().powi(2)
                    + (2.0 * x2).sin().powi(2)
                    + (4.0 * x3).sin().powi(2))
                    / 3.0) as f32;
            }
        }
    }
    f
}

fn probe_at(q: [f64; 3], n: usize) -> f64 {
    let h = 2.0 * PI / n as f64;
    let (x1, x2, x3) = (q[0] * h, q[1] * h, q[2] * h);
    ((8.0 * x1).sin().powi(2) + (2.0 * x2).sin().powi(2) + (4.0 * x3).sin().powi(2)) / 3.0
}

fn main() -> claire::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let sizes: Vec<usize> = if args.is_empty() {
        vec![16, 32, 64]
    } else {
        args[0].split(',').filter_map(|s| s.parse().ok()).collect()
    };
    let reg = OpRegistry::open_default()?;
    let bench = Bench::default();

    // ----------------------------------------------------------------- T2
    println!("== Table 2 analog: arithmetic intensity (analytic model) ==");
    println!("device: {} -> intensity {:.2} FLOP/B\n", V100.name, V100.peak_flops / V100.peak_bw_bytes);
    let mut t2 = Table::new(&["kernel", "FLOPs/pt", "MOPS[B]", "intensity", "bound by"]);
    for k in paper_kernels().iter().chain(our_kernels().iter()) {
        t2.row(&[
            k.name.into(),
            format!("{:.0}", k.flops),
            format!("{:.0}", k.mops_bytes),
            format!("{:.2}", k.intensity()),
            if k.memory_bound(&V100) { "memory".into() } else { "compute (analytic)".into() },
        ]);
    }
    t2.print();

    // ----------------------------------------------------------------- T4
    println!("\n== Table 4 analog: interpolation error + runtime (perturbed grid) ==");
    let mut t4 = Table::new(&["N", "method", "error", "t_syn[s]", "eff.BW[GB/s]"]);
    for &n in &sizes {
        let m = n * n * n;
        let f = probe_field(n);
        // Perturbed grid queries (paper: "randomly perturbed grid points").
        let mut rng = Rng::new(7);
        let mut q = vec![0f32; 3 * m];
        let mut want = vec![0f32; m];
        for idx in 0..m {
            let (i, j, k) = (idx / (n * n), (idx / n) % n, idx % n);
            let qp = [
                i as f64 + rng.uniform_in(-0.5, 0.5),
                j as f64 + rng.uniform_in(-0.5, 0.5),
                k as f64 + rng.uniform_in(-0.5, 0.5),
            ];
            q[idx] = qp[0] as f32;
            q[m + idx] = qp[1] as f32;
            q[2 * m + idx] = qp[2] as f32;
            want[idx] = probe_at(qp, n) as f32;
        }
        for (tag, op_name) in [
            ("GPU-LAG analog (interp_lag)", "interp_lag"),
            ("GPU-TXTSPL analog (interp_spl)", "interp_spl"),
            ("GPU-TXTLIN analog (interp_linbf16)", "interp_linbf16"),
            ("trilinear f32 (interp_lin)", "interp_lin"),
            ("CPU-LAG analog (interp_lag_jnp)", "interp_lag_jnp"),
        ] {
            let op = reg.get(op_name, "opt-fd8-cubic", n)?;
            let mut out = Vec::new();
            let s = bench.run(tag, || out = op.call(&[&f, &q]).unwrap());
            let err = rel_l2(&out[0], &want);
            // MOPS model: 20 B per target point (paper Table 2).
            let bw = s.throughput_gbs(20 * m);
            t4.row(&[
                format!("{n}^3"),
                tag.into(),
                format!("{err:.1e}"),
                fmt_time(s.median_s),
                format!("{bw:.1}"),
            ]);
        }
    }
    t4.print();

    // --------------------------------------------------------------- Fig2
    println!("\n== Figure 2 analog: FFT vs FD8 derivative error over frequency ==");
    let mut csv = String::from("n,omega,err_fd8,err_fft\n");
    let mut fig = Table::new(&["N", "omega", "FD8 err", "FFT err"]);
    for &n in &sizes {
        let grad_fd8 = reg.get("grad_fd8", "opt-fd8-cubic", n)?;
        let grad_fft = reg.get("grad_fft", "opt-fd8-cubic", n)?;
        let m = n * n * n;
        let mut omega = 1.0;
        while omega < n as f64 / 2.0 {
            let f = kernels_ref::fig2_probe(n, omega);
            let want = kernels_ref::fig2_probe_deriv(n, omega);
            let d8 = grad_fd8.call(&[&f])?.remove(0);
            let df = grad_fft.call(&[&f])?.remove(0);
            let e8 = rel_l2(&d8[2 * m..], &want);
            let ef = rel_l2(&df[2 * m..], &want);
            csv.push_str(&format!("{n},{omega},{e8:.3e},{ef:.3e}\n"));
            if omega as usize % 2 == 1 || omega < 4.0 {
                fig.row(&[
                    format!("{n}^3"),
                    format!("{omega}"),
                    format!("{e8:.1e}"),
                    format!("{ef:.1e}"),
                ]);
            }
            omega += 1.0;
        }
    }
    fig.print();
    std::fs::File::create("fig2_accuracy.csv")?.write_all(csv.as_bytes())?;
    println!("full series -> fig2_accuracy.csv");
    println!("\n(expected shape: FFT flat near machine-eps below Nyquist; FD8");
    println!(" error grows with frequency — paper Fig 2.)");
    Ok(())
}
