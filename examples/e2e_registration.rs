//! End-to-end driver: the full system on a realistic small workload.
//!
//! Registers the three NIREP-analog subjects (na02/na03/na10 -> na01) at a
//! configurable resolution with the optimized kernel variant, logging the
//! Gauss-Newton convergence history per run, the paper's Table-7 quality
//! metrics, and the headline claim of the paper scaled to this testbed:
//! *a clinical-size registration in seconds on a single device*.
//!
//! ```bash
//! cargo run --release --example e2e_registration -- [n] [variant]
//! # default: n = 32, variant = opt-fd8-cubic; EXPERIMENTS.md uses n = 64
//! ```
//!
//! Outputs: paper-style table on stdout + `e2e_convergence.csv` +
//! before/after volumes under `e2e_volumes/` for qualitative (Fig 5-like)
//! inspection.

use std::io::Write;

use claire::data::viz::{render_slice, Plane};
use claire::data::{io, synth};
use claire::field::Field3;
use claire::registration::{GnSolver, RegParams, RunReport};
use claire::runtime::OpRegistry;
use claire::util::bench::Table;

fn main() -> claire::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(32);
    let variant = args.get(1).cloned().unwrap_or_else(|| "opt-fd8-cubic".to_string());

    let reg = OpRegistry::open_default()?;
    let params = RegParams { variant: variant.clone(), verbose: true, ..Default::default() };
    let solver = GnSolver::new(&reg, params);
    println!("== e2e: 3 subjects at {n}^3, variant {variant} ==");
    let tc = solver.precompile(n)?;
    println!("operators compiled in {tc:.1}s (one-time per process)\n");

    let mut table = Table::new(&RunReport::headers());
    let mut csv = String::from("subject,iter,beta,J,mismatch_rel,grad_rel,cg_iters,alpha\n");
    let mut total_solve = 0.0;

    for subject in ["na02", "na03", "na10"] {
        println!("-- generating {subject}->na01 ...");
        let prob = synth::nirep_analog_pair(&reg, n, subject)?;
        println!("-- solving {subject}->na01 ...");
        let res = solver.solve(&prob)?;
        total_solve += res.time_s;
        for (it, rec) in res.history.iter().enumerate() {
            csv.push_str(&format!(
                "{subject},{it},{:.1e},{:.6e},{:.4},{:.3e},{},{}\n",
                rec.level_beta, rec.j, rec.mismatch_rel, rec.grad_rel, rec.cg_iters, rec.alpha
            ));
        }
        let report = RunReport::build(&solver, &prob, &res)?;
        table.row(&report.row());

        if subject == "na03" {
            // Fig-5 style qualitative dump for one subject.
            let dir = std::path::PathBuf::from("e2e_volumes");
            std::fs::create_dir_all(&dir)?;
            let warped = solver.transport(&res.v, &prob.m0.data)?;
            let mism_after: Vec<f32> =
                warped.iter().zip(&prob.m1.data).map(|(a, b)| (a - b).abs()).collect();
            let mism_before: Vec<f32> =
                prob.m0.data.iter().zip(&prob.m1.data).map(|(a, b)| (a - b).abs()).collect();
            io::write_field(&dir.join("m0"), &prob.m0, "template")?;
            io::write_field(&dir.join("m1"), &prob.m1, "reference")?;
            io::write_field(
                &dir.join("mismatch_before"),
                &Field3::from_vec(n, mism_before)?,
                "|m0 - m1|",
            )?;
            io::write_field(
                &dir.join("mismatch_after"),
                &Field3::from_vec(n, mism_after)?,
                "|m(1) - m1|",
            )?;
            let detf = solver.detf(&res.v)?;
            io::write_field(&dir.join("detf"), &Field3::from_vec(n, detf)?, "det F")?;
            println!("   qualitative volumes -> e2e_volumes/");
            // Fig-5 style terminal panels: mismatch before vs after.
            let mb = Field3::from_vec(n, prob.m0.data.iter().zip(&prob.m1.data).map(|(a, b)| (a - b).abs()).collect())?;
            let ma = io::read_field(&dir.join("mismatch_after"))?;
            println!("-- mismatch BEFORE (coronal mid-slice) --");
            print!("{}", render_slice(&mb, Plane::Coronal, n / 2, 64));
            println!("-- mismatch AFTER --");
            print!("{}", render_slice(&ma, Plane::Coronal, n / 2, 64));
        }
    }

    println!("\n== results (paper Table 7 analog) ==");
    table.print();
    std::fs::File::create("e2e_convergence.csv")?.write_all(csv.as_bytes())?;
    println!("convergence history -> e2e_convergence.csv");
    println!(
        "\nheadline: 3 registrations at {n}^3 in {total_solve:.2}s solver time \
         ({:.2}s each) on a single CPU device",
        total_solve / 3.0
    );
    Ok(())
}
