//! Per-operator timing profile: the perf-pass instrumentation used for the
//! iteration log in EXPERIMENTS.md section Perf.
//!
//! ```bash
//! cargo run --release --example op_profile -- [n] [variant]
//! ```
use claire::runtime::OpRegistry;
use claire::util::rng::Rng;
use std::time::Instant;

fn main() {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(64);
    let variant = std::env::args().nth(2).unwrap_or_else(|| "opt-fd8-cubic".into());
    let reg = OpRegistry::open_default().unwrap();
    let m = n * n * n;
    let mut rng = Rng::new(1);
    let f: Vec<f32> = (0..m).map(|_| rng.uniform_f32(0.0, 1.0)).collect();
    let v: Vec<f32> = (0..3 * m).map(|_| rng.uniform_f32(-0.3, 0.3)).collect();
    let q: Vec<f32> = (0..3 * m).map(|_| rng.uniform_f32(0.0, n as f32)).collect();
    let traj: Vec<f32> = (0..5 * m).map(|_| rng.uniform_f32(0.0, 1.0)).collect();
    let bg = [5e-4f32, 1e-4];

    let time = |name: &str, inputs: &[&[f32]]| {
        let op = reg.get(name, &variant, n).unwrap();
        op.call(inputs).unwrap();
        let t0 = Instant::now();
        let reps = 3;
        for _ in 0..reps { op.call(inputs).unwrap(); }
        println!("{name:16} {:?}", t0.elapsed() / reps);
    };
    println!("== n={n} variant={variant} ==");
    time("newton_setup", &[&v, &f, &f, &bg]);
    time("hess_matvec", &[&v, &traj, &q, &q, &f, &bg]);
    time("objective", &[&v, &f, &f, &bg]);
    time("precond", &[&v, &bg]);
    time("interp_spl", &[&f, &q]);
    time("interp_linbf16", &[&f, &q]);
    time("prefilter", &[&f]);
    time("grad_fd8", &[&f]);
    time("grad_fft", &[&f]);
    time("reg_apply", &[&v]);
}
