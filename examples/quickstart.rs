//! Quickstart: register one synthetic brain pair and print the metrics.
//!
//! ```bash
//! make artifacts                       # once: AOT-compile the operators
//! cargo run --release --example quickstart
//! ```

use claire::data::synth;
use claire::registration::{GnSolver, RegParams, RunReport};
use claire::runtime::OpRegistry;
use claire::util::bench::Table;

fn main() -> claire::Result<()> {
    // 1. Open the artifact registry (PJRT CPU client + manifest).
    let reg = OpRegistry::open_default()?;

    // 2. Build a synthetic template/reference pair (NIREP na02->na01
    //    analog) at 16^3 — small enough to solve in under a second.
    let prob = synth::nirep_analog_pair(&reg, 16, "na02")?;

    // 3. Solve with the paper's default configuration: Gauss-Newton-Krylov,
    //    beta continuation to 5e-4, FD8 derivatives + cubic B-spline
    //    interpolation kernels (the gpu-fd8-cubic analog).
    let solver = GnSolver::new(&reg, RegParams::default());
    println!("compiling operators (one-time per process) ...");
    let tc = solver.precompile(prob.n())?;
    println!("compiled in {tc:.1}s; solving ...");
    let res = solver.solve(&prob)?;

    // 4. Report the paper's Table-7 metrics.
    let report = RunReport::build(&solver, &prob, &res)?;
    let mut t = Table::new(&RunReport::headers());
    t.row(&report.row());
    t.print();
    println!(
        "\nregistered in {:.2}s ({} Gauss-Newton iters, {} Hessian matvecs)",
        res.time_s, res.iters, res.matvecs
    );
    Ok(())
}
